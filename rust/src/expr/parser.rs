//! Precedence-climbing parser for `EQU` formulas.

use super::ast::{BinOp, Expr};
use crate::error::{Error, Result};

/// Parse a formula string into an expression tree.
///
/// Binary operators are left-associative; `*` `/` bind tighter than
/// `+` `-` (ordinary arithmetic).  A leading `-` (at the start of the
/// expression or after `(` or an operator) is desugared to `0.0 - x`.
pub fn parse(src: &str) -> Result<Expr> {
    let tokens = tokenize(src)?;
    let mut p = Parser { src, tokens, pos: 0 };
    let e = p.expr(0)?;
    if p.pos != p.tokens.len() {
        return Err(p.err(format!(
            "unexpected trailing token `{}`",
            p.tokens[p.pos]
        )));
    }
    Ok(e)
}

#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Num(f64),
    Ident(String),
    Op(char),
    LParen,
    RParen,
}

impl std::fmt::Display for Tok {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Tok::Num(v) => write!(f, "{v}"),
            Tok::Ident(s) => write!(f, "{s}"),
            Tok::Op(c) => write!(f, "{c}"),
            Tok::LParen => write!(f, "("),
            Tok::RParen => write!(f, ")"),
        }
    }
}

fn tokenize(src: &str) -> Result<Vec<Tok>> {
    let mut out = Vec::new();
    let bytes: Vec<char> = src.chars().collect();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i];
        match c {
            ' ' | '\t' | '\r' | '\n' => i += 1,
            '(' => {
                out.push(Tok::LParen);
                i += 1;
            }
            ')' => {
                out.push(Tok::RParen);
                i += 1;
            }
            '+' | '-' | '*' | '/' => {
                out.push(Tok::Op(c));
                i += 1;
            }
            '0'..='9' | '.' => {
                let start = i;
                while i < bytes.len()
                    && (bytes[i].is_ascii_digit()
                        || bytes[i] == '.'
                        || bytes[i] == 'e'
                        || bytes[i] == 'E'
                        || ((bytes[i] == '+' || bytes[i] == '-')
                            && i > start
                            && (bytes[i - 1] == 'e' || bytes[i - 1] == 'E')))
                {
                    i += 1;
                }
                let text: String = bytes[start..i].iter().collect();
                let v = text.parse::<f64>().map_err(|_| Error::Expr {
                    expr: src.to_string(),
                    msg: format!("bad number literal `{text}`"),
                })?;
                out.push(Tok::Num(v));
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                while i < bytes.len()
                    && (bytes[i].is_ascii_alphanumeric()
                        || bytes[i] == '_'
                        || bytes[i] == ':')
                {
                    // allow interface-qualified names like `Mi::sop`
                    if bytes[i] == ':'
                        && !(i + 1 < bytes.len() && bytes[i + 1] == ':')
                        && !(i > start && bytes[i - 1] == ':')
                    {
                        break;
                    }
                    i += 1;
                }
                out.push(Tok::Ident(bytes[start..i].iter().collect()));
            }
            other => {
                return Err(Error::Expr {
                    expr: src.to_string(),
                    msg: format!("unexpected character `{other}`"),
                })
            }
        }
    }
    Ok(out)
}

struct Parser<'a> {
    src: &'a str,
    tokens: Vec<Tok>,
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: String) -> Error {
        Error::Expr { expr: self.src.to_string(), msg }
    }

    fn peek(&self) -> Option<&Tok> {
        self.tokens.get(self.pos)
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.tokens.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn expr(&mut self, min_prec: u8) -> Result<Expr> {
        let mut lhs = self.atom()?;
        while let Some(Tok::Op(c)) = self.peek() {
            let op = match c {
                '+' => BinOp::Add,
                '-' => BinOp::Sub,
                '*' => BinOp::Mul,
                '/' => BinOp::Div,
                _ => unreachable!(),
            };
            if op.precedence() < min_prec {
                break;
            }
            self.next();
            let rhs = self.expr(op.precedence() + 1)?;
            lhs = Expr::bin(op, lhs, rhs);
        }
        Ok(lhs)
    }

    fn atom(&mut self) -> Result<Expr> {
        match self.next() {
            Some(Tok::Num(v)) => Ok(Expr::Num(v)),
            Some(Tok::Ident(name)) => {
                if name == "sqrt" {
                    match self.next() {
                        Some(Tok::LParen) => {}
                        other => {
                            return Err(self.err(format!(
                                "expected `(` after sqrt, got {other:?}"
                            )))
                        }
                    }
                    let inner = self.expr(0)?;
                    match self.next() {
                        Some(Tok::RParen) => Ok(Expr::Sqrt(Box::new(inner))),
                        other => Err(self.err(format!(
                            "expected `)` closing sqrt, got {other:?}"
                        ))),
                    }
                } else {
                    Ok(Expr::Var(name))
                }
            }
            Some(Tok::LParen) => {
                let inner = self.expr(0)?;
                match self.next() {
                    Some(Tok::RParen) => Ok(inner),
                    other => Err(self.err(format!(
                        "expected `)`, got {other:?}"
                    ))),
                }
            }
            Some(Tok::Op('-')) => {
                // unary minus extension: desugar to (0.0 - x)
                let inner = self.atom()?;
                Ok(Expr::bin(BinOp::Sub, Expr::Num(0.0), inner))
            }
            other => Err(self.err(format!("expected operand, got {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::{census, eval, free_vars};
    use crate::prop::{forall, Config};
    use crate::util::XorShift64;
    use std::collections::HashMap;

    fn ev(src: &str, env: &[(&str, f32)]) -> f32 {
        let map: HashMap<String, f32> =
            env.iter().map(|(k, v)| (k.to_string(), *v)).collect();
        eval(&parse(src).unwrap(), &|n| map.get(n).copied()).unwrap()
    }

    #[test]
    fn precedence() {
        assert_eq!(ev("1 + 2 * 3", &[]), 7.0);
        assert_eq!(ev("(1 + 2) * 3", &[]), 9.0);
        assert_eq!(ev("8 / 2 / 2", &[]), 2.0); // left assoc
        assert_eq!(ev("8 - 2 - 2", &[]), 4.0);
    }

    #[test]
    fn sqrt_and_vars() {
        assert_eq!(ev("sqrt(x) + 1", &[("x", 9.0)]), 4.0);
        assert_eq!(ev("a * a - b", &[("a", 3.0), ("b", 1.0)]), 8.0);
    }

    #[test]
    fn unary_minus_desugars() {
        let e = parse("-x + 1").unwrap();
        assert_eq!(census(&e).add, 2); // (0 - x) + 1
        assert_eq!(ev("-x + 1", &[("x", 3.0)]), -2.0);
    }

    #[test]
    fn qualified_names() {
        let e = parse("Mi::sop + x").unwrap();
        assert_eq!(free_vars(&e), vec!["Mi::sop", "x"]);
    }

    #[test]
    fn scientific_literals() {
        assert!((ev("1.5e2", &[]) - 150.0).abs() < 1e-6);
        assert!((ev("2e-2", &[]) - 0.02).abs() < 1e-8);
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse("1 +").is_err());
        assert!(parse("(1 + 2").is_err());
        assert!(parse("sqrt 4").is_err());
        assert!(parse("a $ b").is_err());
        assert!(parse("1 2").is_err());
    }

    // ---- property tests -------------------------------------------

    fn random_expr(rng: &mut XorShift64, depth: usize) -> Expr {
        if depth == 0 || rng.chance(0.3) {
            if rng.chance(0.5) {
                // non-negative: a leading `-` re-parses as (0.0 - x)
                Expr::Num(rng.below(800) as f64 / 8.0)
            } else {
                Expr::Var(format!("v{}", rng.below(5)))
            }
        } else {
            match rng.below(5) {
                0 => Expr::bin(
                    BinOp::Add,
                    random_expr(rng, depth - 1),
                    random_expr(rng, depth - 1),
                ),
                1 => Expr::bin(
                    BinOp::Sub,
                    random_expr(rng, depth - 1),
                    random_expr(rng, depth - 1),
                ),
                2 => Expr::bin(
                    BinOp::Mul,
                    random_expr(rng, depth - 1),
                    random_expr(rng, depth - 1),
                ),
                3 => Expr::bin(
                    BinOp::Div,
                    random_expr(rng, depth - 1),
                    random_expr(rng, depth - 1),
                ),
                _ => Expr::Sqrt(Box::new(random_expr(rng, depth - 1))),
            }
        }
    }

    #[test]
    fn prop_print_parse_roundtrip() {
        forall(Config::cases(200).seed(11), |rng| {
            let e = random_expr(rng, 4);
            let printed = e.to_string();
            let back = parse(&printed)
                .map_err(|err| format!("reparse of `{printed}`: {err}"))?;
            if back != e {
                return Err(format!("round-trip mismatch: `{printed}`"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_roundtrip_preserves_census_and_eval() {
        forall(Config::cases(200).seed(13), |rng| {
            let e = random_expr(rng, 4);
            let back = parse(&e.to_string()).unwrap();
            if census(&back) != census(&e) {
                return Err("census changed".into());
            }
            let env: HashMap<String, f32> = (0..5)
                .map(|i| (format!("v{i}"), rng.range_f32(0.5, 4.0)))
                .collect();
            let a = eval(&e, &|n| env.get(n).copied()).unwrap();
            let b = eval(&back, &|n| env.get(n).copied()).unwrap();
            // identical trees must evaluate bit-identically
            if a.to_bits() != b.to_bits() && !(a.is_nan() && b.is_nan()) {
                return Err(format!("eval mismatch {a} vs {b}"));
            }
            Ok(())
        });
    }
}
