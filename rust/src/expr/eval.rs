//! Reference (software) evaluation of expressions in f32.
//!
//! The hardware datapath is single precision (paper §II-C1: "all related
//! variables are treated as single precision floating-point numbers"),
//! so evaluation is done in `f32` with one rounding per operator — the
//! same numerics the elaborated DFG produces.

use super::ast::{BinOp, Expr};
use crate::error::{Error, Result};

/// Evaluate an expression; `env` resolves free variables.
pub fn eval(e: &Expr, env: &dyn Fn(&str) -> Option<f32>) -> Result<f32> {
    match e {
        Expr::Num(v) => Ok(*v as f32),
        Expr::Var(name) => env(name).ok_or_else(|| Error::Expr {
            expr: e.to_string(),
            msg: format!("unbound variable `{name}`"),
        }),
        Expr::Sqrt(x) => Ok(eval(x, env)?.sqrt()),
        Expr::Bin(op, a, b) => {
            let a = eval(a, env)?;
            let b = eval(b, env)?;
            Ok(apply(*op, a, b))
        }
    }
}

/// One hardware operator application (single f32 rounding).
#[inline]
pub fn apply(op: BinOp, a: f32, b: f32) -> f32 {
    match op {
        BinOp::Add => a + b,
        BinOp::Sub => a - b,
        BinOp::Mul => a * b,
        BinOp::Div => a / b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expr::parse;

    #[test]
    fn eval_is_f32_rounded() {
        // 0.1 + 0.2 in f32 differs from f64 rounding
        let e = parse("0.1 + 0.2").unwrap();
        let v = eval(&e, &|_| None).unwrap();
        assert_eq!(v, 0.1f32 + 0.2f32);
    }

    #[test]
    fn unbound_variable_errors() {
        let e = parse("x + 1").unwrap();
        assert!(eval(&e, &|_| None).is_err());
    }

    #[test]
    fn division_by_zero_is_ieee() {
        let e = parse("1.0 / x").unwrap();
        let v = eval(&e, &|_| Some(0.0)).unwrap();
        assert!(v.is_infinite());
    }
}
