//! Formula expression engine for `EQU` nodes (paper Table II).
//!
//! Grammar (paper §II-C): parentheses, binary `+ - * /`, the `sqrt()`
//! function, numeric literals, and identifiers (input ports, node
//! outputs, or `Param` constants).  As a convenience extension a leading
//! unary minus is accepted and desugared to `0.0 - x` (the SPD grammar
//! itself has no unary operator; the desugaring makes the hardware cost
//! explicit — it becomes a real subtractor).

pub mod ast;
pub mod eval;
pub mod parser;

pub use ast::{BinOp, Expr};
pub use eval::eval;
pub use parser::parse;

/// Floating-point operator census of an expression (paper Table IV).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCensus {
    pub add: usize,
    pub mul: usize,
    pub div: usize,
    pub sqrt: usize,
}

impl OpCensus {
    pub fn total(&self) -> usize {
        self.add + self.mul + self.div + self.sqrt
    }

    pub fn accumulate(&mut self, other: &OpCensus) {
        self.add += other.add;
        self.mul += other.mul;
        self.div += other.div;
        self.sqrt += other.sqrt;
    }
}

/// Count FP operators in an expression.  Additions and subtractions are
/// both "Adder" in the paper's Table IV.
pub fn census(e: &Expr) -> OpCensus {
    let mut c = OpCensus::default();
    walk_census(e, &mut c);
    c
}

fn walk_census(e: &Expr, c: &mut OpCensus) {
    match e {
        Expr::Num(_) | Expr::Var(_) => {}
        Expr::Sqrt(x) => {
            c.sqrt += 1;
            walk_census(x, c);
        }
        Expr::Bin(op, a, b) => {
            match op {
                BinOp::Add | BinOp::Sub => c.add += 1,
                BinOp::Mul => c.mul += 1,
                BinOp::Div => c.div += 1,
            }
            walk_census(a, c);
            walk_census(b, c);
        }
    }
}

/// Collect the free variables (port references) of an expression, in
/// first-occurrence order without duplicates.
pub fn free_vars(e: &Expr) -> Vec<String> {
    let mut out = Vec::new();
    walk_vars(e, &mut out);
    out
}

fn walk_vars(e: &Expr, out: &mut Vec<String>) {
    match e {
        Expr::Num(_) => {}
        Expr::Var(v) => {
            if !out.iter().any(|x| x == v) {
                out.push(v.clone());
            }
        }
        Expr::Sqrt(x) => walk_vars(x, out),
        Expr::Bin(_, a, b) => {
            walk_vars(a, out);
            walk_vars(b, out);
        }
    }
}

/// Substitute `Param` constants into an expression (the preprocessor's
/// static replacement, paper §II-C1).
pub fn substitute_params(e: &Expr, params: &dyn Fn(&str) -> Option<f64>) -> Expr {
    match e {
        Expr::Num(v) => Expr::Num(*v),
        Expr::Var(v) => match params(v) {
            Some(c) => Expr::Num(c),
            None => Expr::Var(v.clone()),
        },
        Expr::Sqrt(x) => Expr::Sqrt(Box::new(substitute_params(x, params))),
        Expr::Bin(op, a, b) => Expr::Bin(
            *op,
            Box::new(substitute_params(a, params)),
            Box::new(substitute_params(b, params)),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> Expr {
        parse(s).unwrap()
    }

    #[test]
    fn census_counts_table2_example() {
        // out = ( in1 + in2 * ( t1 - t2 ) ) / in3 + sqrt( in4 )
        let e = p("( in1 + in2 * ( t1 - t2 ) ) / in3 + sqrt( in4 )");
        let c = census(&e);
        assert_eq!(c.add, 3); // +, -, +
        assert_eq!(c.mul, 1);
        assert_eq!(c.div, 1);
        assert_eq!(c.sqrt, 1);
        assert_eq!(c.total(), 6);
    }

    #[test]
    fn free_vars_order_and_dedup() {
        let e = p("a * b + a - c");
        assert_eq!(free_vars(&e), vec!["a", "b", "c"]);
    }

    #[test]
    fn substitute_replaces_params_only() {
        let e = p("x * cnst + y");
        let s = substitute_params(&e, &|n| (n == "cnst").then_some(123.456));
        assert_eq!(free_vars(&s), vec!["x", "y"]);
        let mut env = std::collections::HashMap::new();
        env.insert("x".to_string(), 2.0f32);
        env.insert("y".to_string(), 1.0f32);
        let v = eval(&s, &|n| env.get(n).copied()).unwrap();
        assert!((v - (2.0 * 123.456f32 + 1.0)).abs() < 1e-3);
    }
}
