//! Expression AST for `EQU` formulas.

use std::fmt;

/// Binary operator (paper §II-C1: `+ - * /`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
}

impl BinOp {
    pub fn symbol(&self) -> char {
        match self {
            BinOp::Add => '+',
            BinOp::Sub => '-',
            BinOp::Mul => '*',
            BinOp::Div => '/',
        }
    }

    /// Binding power (higher binds tighter).
    pub fn precedence(&self) -> u8 {
        match self {
            BinOp::Add | BinOp::Sub => 1,
            BinOp::Mul | BinOp::Div => 2,
        }
    }
}

/// Expression tree.  Every interior node becomes one hardware operator
/// in the DFG (the compiler performs no cross-node CSE — paper Fig. 3).
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    /// Numeric literal (f64 in the AST; hardware is single precision).
    Num(f64),
    /// Port or parameter reference.
    Var(String),
    /// Binary arithmetic.
    Bin(BinOp, Box<Expr>, Box<Expr>),
    /// Square-root function.
    Sqrt(Box<Expr>),
}

impl Expr {
    pub fn bin(op: BinOp, a: Expr, b: Expr) -> Expr {
        Expr::Bin(op, Box::new(a), Box::new(b))
    }

    pub fn var(name: impl Into<String>) -> Expr {
        Expr::Var(name.into())
    }
}

impl fmt::Display for Expr {
    /// Fully-parenthesized rendering: re-parsing the output yields an
    /// identical tree (round-trip property tested in `parser.rs`).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Num(v) => {
                if *v == v.trunc() && v.abs() < 1e15 {
                    write!(f, "{v:.1}")
                } else {
                    write!(f, "{v}")
                }
            }
            Expr::Var(n) => write!(f, "{n}"),
            Expr::Sqrt(x) => write!(f, "sqrt({x})"),
            Expr::Bin(op, a, b) => write!(f, "({a} {} {b})", op.symbol()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_parenthesizes() {
        let e = Expr::bin(
            BinOp::Add,
            Expr::var("a"),
            Expr::bin(BinOp::Mul, Expr::var("b"), Expr::Num(2.0)),
        );
        assert_eq!(e.to_string(), "(a + (b * 2.0))");
    }

    #[test]
    fn precedence_ordering() {
        assert!(BinOp::Mul.precedence() > BinOp::Add.precedence());
        assert_eq!(BinOp::Add.precedence(), BinOp::Sub.precedence());
    }
}
