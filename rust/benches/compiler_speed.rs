//! Compiler-productivity metric (the paper's motivation is design
//! productivity): time to go from SPD text to a scheduled pipeline,
//! per core and for full designs.

mod common;

use common::{bench, section};
use spdx::dfg;
use spdx::lbm::spd_gen::{gen_bndry, gen_calc, generate, LbmDesign};
use spdx::spd::{parse_core, Registry};

fn main() {
    section("front-end: parse");
    let calc_src = gen_calc();
    let bndry_src = gen_bndry();
    bench("parse uLBM_calc (76 statements)", 5, 30, || {
        let _ = parse_core(&calc_src).unwrap();
    });
    bench("parse uLBM_bndry", 5, 30, || {
        let _ = parse_core(&bndry_src).unwrap();
    });

    section("middle-end: build + elaborate + schedule");
    let mut reg = Registry::with_library();
    let calc = reg.register_source(&calc_src).unwrap();
    bench("compile uLBM_calc", 5, 30, || {
        let c = dfg::compile(&calc, &reg).unwrap();
        assert_eq!(c.depth(), 110);
    });

    section("full designs (SPD generation + compile, W=720)");
    for (n, m) in [(1u32, 1u32), (1, 4), (4, 1)] {
        bench(&format!("generate+compile (n={n}, m={m})"), 1, 10, || {
            let g = generate(&LbmDesign::new(n, m, 720, 300)).unwrap();
            let c = dfg::compile(&g.top, &g.registry).unwrap();
            assert_eq!(c.graph.census().total() as u32, 131 * n * m);
        });
    }
}
