//! DSE: the full exploration loop (paper §III / §IV "automate the
//! process of design space exploration") — sweep timing, parallel
//! speedup of the coordinator, per-workload sweep cost, cached-vs-cold
//! sweeps through the `EvalCache`, strategy comparison, and the
//! headline conclusions.

mod common;

use common::{bench, section};
use spdx::coordinator::Coordinator;
use spdx::dse::{
    BoundedPrune, DesignSpace, EvalCache, Exhaustive, SearchStrategy, SweepContext,
};
use spdx::explore::{explore, ExploreConfig};
use spdx::obs::Obs;
use spdx::workload;

fn main() {
    let cfg = ExploreConfig {
        max_n: 4,
        max_m: 4,
        passes: 2,
        keep_infeasible: true,
        ..Default::default()
    };

    // explore() itself now runs on the full worker pool; for the
    // sequential-vs-parallel comparison, pin the coordinator to one
    // worker explicitly.
    section("sequential exploration (16 candidates, 720x300, 1 worker)");
    let coord_seq = Coordinator::new(cfg).with_workers(1);
    let s_seq = bench("coordinator, 1 worker", 0, 3, || {
        let (evals, _) = coord_seq.run().unwrap();
        assert!(!evals.is_empty());
    });

    section("coordinator (multi-threaded)");
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let coord = Coordinator::new(cfg).with_workers(workers);
    let s_par = bench(&format!("coordinator, {workers} workers"), 0, 3, || {
        let (evals, _) = coord.run().unwrap();
        assert!(!evals.is_empty());
    });
    println!(
        "  -> parallel speedup {:.2}x on {workers} workers",
        s_seq.median / s_par.median
    );

    section("eval cache: cold vs warm sweep (16 candidates, 720x300)");
    let space = DesignSpace::from_explore(&cfg);
    let s_cold = bench("exhaustive sweep, cold cache", 0, 3, || {
        // a fresh cache every iteration: every point recomputed
        let cache = EvalCache::new();
        let ctx = SweepContext::new(&cache, workers);
        let r = Exhaustive.run(&space, &ctx).unwrap();
        assert_eq!(r.cache_hits, 0);
        assert!(r.evaluated > 0);
    });
    let warm_cache = EvalCache::new();
    let warm_ctx = SweepContext::new(&warm_cache, workers);
    Exhaustive.run(&space, &warm_ctx).unwrap();
    let s_warm = bench("exhaustive sweep, warm cache", 0, 3, || {
        let r = Exhaustive.run(&space, &warm_ctx).unwrap();
        assert_eq!(r.evaluated, 0, "warm sweep must recompute nothing");
        assert!(r.cache_hits > 0);
    });
    println!(
        "  -> cache speedup {:.0}x (cold {:.1} ms -> warm {:.2} ms)",
        s_cold.median / s_warm.median,
        s_cold.median * 1e3,
        s_warm.median * 1e3
    );
    // the BENCH_dse trajectory numbers (also emitted by
    // `dse sweep --bench`): evaluations per wall second
    println!(
        "  -> throughput: cold {:.0} evals/sec, warm {:.0} evals/sec (16 candidates)",
        16.0 / s_cold.median,
        16.0 / s_warm.median
    );

    section("observability overhead: metrics registry on the warm sweep");
    {
        // warm sweeps are the worst case for telemetry overhead: every
        // lookup is a cache hit, so the per-row bookkeeping is the
        // largest fraction of the work
        let s_bare = bench("warm sweep, no telemetry", 0, 3, || {
            let r = Exhaustive.run(&space, &warm_ctx).unwrap();
            assert_eq!(r.evaluated, 0);
        });
        let obs = Obs::new();
        let obs_ctx = SweepContext::new(&warm_cache, workers).with_obs(&obs);
        let s_obs = bench("warm sweep, metrics registry", 0, 3, || {
            let r = Exhaustive.run(&space, &obs_ctx).unwrap();
            assert_eq!(r.evaluated, 0);
        });
        println!(
            "  -> telemetry overhead {:+.1}% on the warm path ({:.2} -> {:.2} ms)",
            100.0 * (s_obs.median / s_bare.median - 1.0),
            s_bare.median * 1e3,
            s_obs.median * 1e3
        );
        assert!(obs.metrics.counter("sweep.cache_hits").get() > 0);
    }

    section("strategy comparison: pruning vs exhaustive evaluation counts");
    {
        let cache = EvalCache::new();
        let ctx = SweepContext::new(&cache, workers);
        let pr = BoundedPrune::default().run(&space, &ctx).unwrap();
        println!(
            "  bounded-prune: {} of {} candidates evaluated, {} pruned \
             (same frontier as exhaustive)",
            pr.evaluated, pr.candidates, pr.skipped
        );
        assert!(pr.evaluated < pr.candidates, "the 4x4 space has prunable points");
        assert_eq!(pr.evaluated + pr.skipped, pr.candidates);
    }

    section("per-workload sweep cost (6 candidates, 360x180)");
    for name in workload::names() {
        let wcfg = ExploreConfig {
            workload: name,
            grid_w: 360,
            grid_h: 180,
            max_n: 4,
            max_m: 2,
            passes: 2,
            keep_infeasible: true,
            ..Default::default()
        };
        bench(&format!("explore() {name}"), 0, 3, || {
            let evals = explore(&wcfg).unwrap();
            assert!(!evals.is_empty());
            // every workload must produce at least one feasible design
            assert!(evals.iter().any(|e| e.infeasible.is_none()), "{name}");
        });
    }

    section("headline conclusions");
    let (evals, _) = coord.run().unwrap();
    let feasible: Vec<_> = evals.iter().filter(|e| e.infeasible.is_none()).collect();
    let best = feasible
        .iter()
        .max_by(|a, b| a.perf_per_watt.total_cmp(&b.perf_per_watt))
        .unwrap();
    println!(
        "  best perf/W: (n={}, m={}) {:.3} GFlop/sW (paper: (1,4) at 2.416)",
        best.design.n, best.design.m, best.perf_per_watt
    );
    assert_eq!((best.design.n, best.design.m), (1, 4));
    // every x1 design keeps u ~ 0.999; every n>1 design is BW-bound
    for e in &feasible {
        if e.design.n == 1 {
            assert!(e.timing.utilization > 0.99);
        } else {
            assert!(e.timing.utilization < 0.6);
        }
    }
    println!("  bandwidth-bound designs: all n > 1 (paper §III-C)  OK");
}
