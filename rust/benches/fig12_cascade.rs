//! F10–F12 + Fig. 2 discussion: temporal-parallelism scaling.
//!
//! Regenerates (a) the cascade structures of Figs. 10–12 (PE depths and
//! cascade depths), (b) the speedup series of cascading m PEs — the
//! paper's m(T+d) vs (T+md) cycle argument — and (c) the
//! prologue/epilogue utilization degradation for short streams that
//! §II-B warns about ("The total effective performance can be much
//! degraded when a short stream goes through a long pipeline").

mod common;

use common::section;
use spdx::explore::{evaluate, ExploreConfig};
use spdx::lbm::spd_gen::{generate, LbmDesign};

fn main() {
    section("Figs. 10-12 — cascade structure (W = 720)");
    for m in [1u32, 2, 4] {
        let d = LbmDesign::new(1, m, 720, 300);
        let g = generate(&d).unwrap();
        let c = spdx::dfg::compile(&g.top, &g.registry).unwrap();
        println!(
            "  m={m}: PE depth {} stages, cascade depth {} stages",
            g.pe_depth,
            c.depth()
        );
        assert_eq!(g.pe_depth, 855);
        assert_eq!(c.depth(), 855 * m);
    }

    section("speedup of m-cascade vs m sequential passes (720x300)");
    // analytic cycle model of §II-B: single PE needs m(T+d) cycles for
    // m steps; the cascade needs (T+md).  Compare with the simulated
    // sustained throughput ratio.
    let t = 720.0 * 300.0;
    let d = 855.0;
    let cfg = ExploreConfig { passes: 3, ..Default::default() };
    let base = evaluate(&LbmDesign::new(1, 1, 720, 300), &cfg).unwrap();
    println!(
        "{:>3} {:>12} {:>12} {:>10} {:>12}",
        "m", "analytic", "simulated", "peak", "GFlop/s"
    );
    for m in [1u32, 2, 4] {
        let e = evaluate(&LbmDesign::new(1, m, 720, 300), &cfg).unwrap();
        let analytic = (m as f64) * (t + d) / (t + m as f64 * d);
        let simulated = e.timing.sustained_gflops / base.timing.sustained_gflops;
        println!(
            "{:>3} {:>11.3}x {:>11.3}x {:>9.1} {:>11.1}",
            m, analytic, simulated, e.timing.peak_gflops, e.timing.sustained_gflops
        );
        assert!(
            (simulated - analytic).abs() / analytic < 0.05,
            "m={m}: simulated speedup {simulated:.3} vs analytic {analytic:.3}"
        );
    }

    section("prologue/epilogue effect: utilization vs stream length");
    // sustained/peak ratio of the (1,4) cascade as the grid shrinks:
    // the 3420-stage pipeline starves on short streams.
    println!("{:>10} {:>8} {:>14} {:>12}", "grid", "cells", "sustained/peak", "note");
    for (w, h) in [(720u32, 300u32), (360, 150), (180, 72), (90, 36), (60, 24)] {
        let e = evaluate(&LbmDesign::new(1, 4, w, h), &cfg).unwrap();
        let ratio = e.timing.sustained_gflops / e.timing.peak_gflops;
        let note = if ratio > 0.95 {
            "pipeline amortized"
        } else if ratio > 0.8 {
            "fill/drain visible"
        } else {
            "short-stream penalty"
        };
        println!(
            "{:>6}x{:<4} {:>8} {:>13.3} {:>20}",
            w,
            h,
            w * h,
            ratio,
            note
        );
    }
    // the paper's point: at 720x300 the penalty is negligible...
    let big = evaluate(&LbmDesign::new(1, 4, 720, 300), &cfg).unwrap();
    assert!(big.timing.sustained_gflops / big.timing.peak_gflops > 0.95);
    // ...but a 16x smaller grid pays a visible fill/drain cost
    let small = evaluate(&LbmDesign::new(1, 4, 90, 36), &cfg).unwrap();
    assert!(small.timing.sustained_gflops / small.timing.peak_gflops < 0.90);
}
