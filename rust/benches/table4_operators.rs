//! T4: regenerate Table IV — the floating-point operator census of a
//! PE pipeline — and verify it is exact at every spatial width.

mod common;

use common::{bench, section};
use spdx::dfg;
use spdx::lbm::spd_gen::{generate, LbmDesign};
use spdx::report;

fn main() {
    section("Table IV — FP operators in a core (x1 pipeline)");
    let g = generate(&LbmDesign::new(1, 1, 720, 300)).expect("generate");
    let c = dfg::compile(&g.top, &g.registry).expect("compile");
    let census = c.graph.census();
    println!("{}", report::table4(&census));
    assert_eq!(census.add, 70, "Adder");
    assert_eq!(census.mul, 60, "Multiplier");
    assert_eq!(census.div, 1, "Divider");
    assert_eq!(census.total(), 131, "Total");

    section("census scales exactly with n*m");
    for (n, m) in [(2u32, 1u32), (4, 1), (1, 2), (1, 4), (2, 2)] {
        let g = generate(&LbmDesign::new(n, m, 720, 300)).unwrap();
        let c = dfg::compile(&g.top, &g.registry).unwrap();
        let total = c.graph.census().total();
        println!("  (n={n}, m={m}): {total} FP operators (= {})", 131 * n * m);
        assert_eq!(total as u32, 131 * n * m);
    }

    section("census computation speed");
    let g = generate(&LbmDesign::new(1, 4, 720, 300)).unwrap();
    let c = dfg::compile(&g.top, &g.registry).unwrap();
    bench("census of flat (1,4) graph", 3, 20, || {
        let s = c.graph.census();
        assert_eq!(s.total(), 4 * 131);
    });
}
