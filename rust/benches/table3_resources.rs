//! T3-res: regenerate Table III's resource columns (ALM / Regs / BRAM /
//! DSP) for the paper's six designs and report deltas vs the measured
//! values, plus the time the structural estimator takes per design.

mod common;

use common::{bench, section};
use spdx::dfg::OpLatency;
use spdx::lbm::spd_gen::{generate, LbmDesign};
use spdx::power::PAPER_TABLE3;
use spdx::resource::{estimate_hierarchical, CostTable, DesignMeta, STRATIX_V_5SGXEA7};
use spdx::util::commas;

fn main() {
    section("Table III — resource columns (model vs paper)");
    println!(
        "{:<8} {:>9} {:>9} {:>6} | {:>10} {:>10} {:>6} | {:>11} {:>11} {:>6} | {:>5} {:>5}",
        "(n,m)", "ALM", "paper", "d%", "Regs", "paper", "d%", "BRAM", "paper", "d%", "DSP", "ppr"
    );
    let mut worst: (f64, &str) = (0.0, "");
    for d in LbmDesign::paper_designs() {
        let g = generate(&d).expect("generate");
        let est = estimate_hierarchical(
            &g.top,
            &g.registry,
            OpLatency::default(),
            &DesignMeta { lanes: d.n, pes: d.m },
            &CostTable::default(),
            &STRATIX_V_5SGXEA7,
        )
        .expect("estimate");
        let p = PAPER_TABLE3
            .iter()
            .find(|p| p.n == d.n && p.m == d.m)
            .unwrap();
        let dp = |ours: f64, paper: f64| 100.0 * (ours - paper) / paper;
        let (da, dr, db) = (
            dp(est.core.alms as f64, p.alms),
            dp(est.core.regs as f64, p.regs),
            dp(est.core.bram_bits as f64, p.bram_bits),
        );
        for (v, tag) in [(da, "ALM"), (dr, "Regs"), (db, "BRAM")] {
            if v.abs() > worst.0.abs() {
                worst = (v, tag);
            }
        }
        println!(
            "({}, {})   {:>9} {:>9} {:>6.1} | {:>10} {:>10} {:>6.1} | {:>11} {:>11} {:>6.1} | {:>5} {:>5}",
            d.n,
            d.m,
            commas(est.core.alms),
            commas(p.alms as u64),
            da,
            commas(est.core.regs),
            commas(p.regs as u64),
            dr,
            commas(est.core.bram_bits),
            commas(p.bram_bits as u64),
            db,
            est.core.dsps,
            p.dsps as u64,
        );
        assert_eq!(est.core.dsps, p.dsps as u64, "DSP column must be exact");
    }
    println!("worst relative error: {:+.1}% ({})", worst.0, worst.1);

    section("estimator speed");
    let d = LbmDesign::new(1, 4, 720, 300);
    let g = generate(&d).unwrap();
    bench("estimate_hierarchical (1,4) @720x300", 2, 10, || {
        let _ = estimate_hierarchical(
            &g.top,
            &g.registry,
            OpLatency::default(),
            &DesignMeta { lanes: 1, pes: 4 },
            &CostTable::default(),
            &STRATIX_V_5SGXEA7,
        )
        .unwrap();
    });
}
