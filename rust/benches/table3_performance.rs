//! T3-perf / T3-power: regenerate Table III's utilization, sustained
//! performance, power and perf/W columns via the cycle-level timing
//! simulation against the DDR3 model, and compare with the paper.

mod common;

use common::{bench, section};
use spdx::explore::{evaluate, ExploreConfig};
use spdx::lbm::spd_gen::LbmDesign;
use spdx::power::PAPER_TABLE3;

fn main() {
    let cfg = ExploreConfig { passes: 3, ..Default::default() };

    section("Table III — utilization / performance / power (model vs paper)");
    println!(
        "{:<8} {:>7} {:>7} | {:>8} {:>8} {:>6} | {:>6} {:>6} | {:>7} {:>7}",
        "(n,m)", "u", "paper", "GFlop/s", "paper", "d%", "P[W]", "paper", "GF/sW", "paper"
    );
    for d in LbmDesign::paper_designs() {
        let e = evaluate(&d, &cfg).expect("evaluate");
        let p = PAPER_TABLE3
            .iter()
            .find(|p| p.n == d.n && p.m == d.m)
            .unwrap();
        println!(
            "({}, {})   {:>7.3} {:>7.3} | {:>8.1} {:>8.1} {:>6.1} | {:>6.1} {:>6.1} | {:>7.3} {:>7.3}",
            d.n,
            d.m,
            e.timing.utilization,
            p.utilization,
            e.timing.performance_gflops,
            p.performance_gflops,
            100.0 * (e.timing.performance_gflops - p.performance_gflops)
                / p.performance_gflops,
            e.power_w,
            p.power_w,
            e.perf_per_watt,
            p.perf_per_watt,
        );
        // the reproduction bands: utilization within 1%, performance
        // within 2%, power within 6%
        assert!((e.timing.utilization - p.utilization).abs() / p.utilization < 0.01);
        assert!(
            (e.timing.performance_gflops - p.performance_gflops).abs()
                / p.performance_gflops
                < 0.02
        );
        assert!((e.power_w - p.power_w).abs() / p.power_w < 0.06);
    }

    // eq. (10): peak performance at nm = 4 is 94.32 GFlop/s
    let e14 = evaluate(&LbmDesign::new(1, 4, 720, 300), &cfg).unwrap();
    println!(
        "\neq. (10) peak at nm=4: {:.2} GFlop/s (paper: 94.32)",
        e14.timing.peak_gflops
    );
    assert!((e14.timing.peak_gflops - 94.32).abs() < 0.05);

    section("timing-simulation speed (720x300 grid)");
    for d in [LbmDesign::new(1, 1, 720, 300), LbmDesign::new(1, 4, 720, 300)] {
        bench(
            &format!("evaluate (n={}, m={}), 3 passes", d.n, d.m),
            1,
            5,
            || {
                let _ = evaluate(&d, &cfg).unwrap();
            },
        );
    }
}
