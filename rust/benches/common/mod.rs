#![allow(dead_code)]
//! Shared mini-bench harness (criterion is not in the offline crate
//! set): warmup + timed runs + robust summary.

use spdx::util::stats::{summarize, time_runs, Summary};

pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> Summary {
    let samples = time_runs(warmup, iters, f);
    let s = summarize(&samples);
    println!(
        "{name:<44} median {:>10.3} ms  (mad {:>7.3} ms, n={})",
        s.median * 1e3,
        s.mad * 1e3,
        s.n
    );
    s
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n==== {title} ====");
}
