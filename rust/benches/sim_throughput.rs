//! §Perf L3 target: throughput of the three simulation engines —
//! the numbers the EXPERIMENTS.md §Perf section tracks.
//!
//!   * timing simulation: cycles/s (Table III runs must take seconds);
//!   * dataflow evaluation: cell-steps/s (numerical verification);
//!   * cycle-accurate engine: cycles/s (register-exact runs).

mod common;

use common::{bench, section};
use spdx::explore::{evaluate, ExploreConfig};
use spdx::lbm::reference::LbmState;
use spdx::lbm::spd_gen::LbmDesign;
use spdx::lbm::workload::LbmRunner;

fn main() {
    section("timing simulation (720x300, 3 passes)");
    let cfg = ExploreConfig { passes: 3, ..Default::default() };
    let d11 = LbmDesign::new(1, 1, 720, 300);
    let e = evaluate(&d11, &cfg).unwrap();
    let cycles = e.timing.total_cycles as f64 * cfg.passes as f64 / cfg.passes as f64;
    let s = bench("timing sim (1,1), 3 passes", 1, 5, || {
        let _ = evaluate(&d11, &cfg).unwrap();
    });
    println!(
        "  -> {:.1} Mcycle/s simulated ({} cycles per run incl. compile+estimate)",
        cycles / s.median / 1e6,
        e.timing.total_cycles
    );

    section("dataflow evaluation (64x64 cavity)");
    let runner = LbmRunner::new(LbmDesign::new(1, 1, 64, 64)).unwrap();
    let state = LbmState::cavity(64, 64);
    let steps = 20u32;
    let s = bench("dataflow 20 steps @64x64", 1, 5, || {
        let _ = runner.run_dataflow(state.clone(), 1.0 / 0.6, steps).unwrap();
    });
    let cellsteps = 64.0 * 64.0 * steps as f64;
    println!("  -> {:.2} Mcell-step/s", cellsteps / s.median / 1e6);

    section("cycle-accurate engine (32x32 cavity)");
    let runner32 = LbmRunner::new(LbmDesign::new(1, 1, 32, 32)).unwrap();
    let state32 = LbmState::cavity(32, 32);
    let s = bench("cycle engine 4 steps @32x32", 1, 3, || {
        let _ = runner32.run_cycle_accurate(state32.clone(), 1.0 / 0.6, 4).unwrap();
    });
    let (_, cycles) = runner32
        .run_cycle_accurate(state32.clone(), 1.0 / 0.6, 4)
        .unwrap();
    println!("  -> {:.2} Mcycle/s through {} graph nodes", cycles as f64 / s.median / 1e6, runner32.compiled.graph.len());

    section("software reference (64x64 cavity)");
    let s = bench("rust reference 20 steps @64x64", 1, 5, || {
        let _ = spdx::lbm::reference::run(state.clone(), 1.0 / 0.6, steps as usize);
    });
    println!("  -> {:.2} Mcell-step/s", cellsteps / s.median / 1e6);
}
