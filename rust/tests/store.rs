//! Concurrency, corruption and equivalence battery for the persistent
//! cross-process evaluation store (`dse::store`).
//!
//! The store's contract is the journal's, plus sharing:
//!
//! 1. **no lost or duplicated rows** — independent handles racing
//!    appends over one directory serialize through the lock file and
//!    converge to exactly one record per content address
//!    (`concurrent_handles_race_appends_without_losing_or_duplicating_rows`);
//! 2. **recovery is exact** — for *every* truncation point of the data
//!    file, open keeps precisely the records fully inside the prefix,
//!    bit-identically, repairing only the torn tail
//!    (`recovery_at_every_byte_boundary_keeps_the_intact_prefix`);
//! 3. **corruption is refused, not repaired** — newline-terminated
//!    garbage, unknown record kinds, rows before the header, duplicate
//!    headers, and out-of-range schema versions all fail open with a
//!    named error and the file untouched;
//! 4. **the store is an accelerator** — a vanished directory degrades
//!    the handle to in-memory-only mid-sweep (gauge raised, sweep
//!    intact), and a sweep through the store is bit-identical to one
//!    without, with a second cold process recomputing nothing;
//! 5. **quarantine is honored** — a `FailRow` identity is never
//!    persisted as a success; a later fault-free retry supersedes it
//!    and the third process reads it from disk.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use spdx::coordinator::{Fault, FaultKind, FaultPlan, Supervisor};
use spdx::dse::json::Json;
use spdx::dse::{
    BoundedPrune, CacheKey, DesignSpace, EvalCache, Exhaustive, HillClimb,
    SearchStrategy, Store, StorePaths, StoreScope, SweepContext, SweepResult,
    STORE_DIR_ENV, STORE_SCHEMA_VERSION,
};
use spdx::explore::Evaluation;
use spdx::obs::Obs;
use spdx::resource::STRATIX_V_5SGXEA7;
use spdx::workload::{self, DesignPoint};

/// Serializes the tests that set `DSE_CACHE_DIR` (env vars are
/// process-global; the test harness runs threads in parallel).
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn env_lock() -> MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn small_space(workload: &'static str) -> DesignSpace {
    DesignSpace {
        workload,
        grids: vec![(32, 16)],
        max_n: 2,
        max_m: 4,
        devices: vec![&STRATIX_V_5SGXEA7],
        ddr_variants: vec![Default::default()],
        passes: 2,
        latency: Default::default(),
    }
}

fn tmp(tag: &str) -> StorePaths {
    StorePaths::in_dir(
        std::env::temp_dir()
            .join(format!("spdx_store_{tag}_{}", std::process::id())),
    )
}

fn cleanup(paths: &StorePaths) {
    std::fs::remove_dir_all(&paths.dir).ok();
}

/// The content address of one candidate of `space` — what the store
/// indexes rows under.
fn key_for(space: &DesignSpace, n: u32, m: u32) -> CacheKey {
    let (w, h) = space.grids[0];
    CacheKey::from_parts(
        space.workload,
        &DesignPoint::new(n, m, w, h),
        space.devices[0].name,
        space.passes,
        space.latency,
        space.ddr_variants[0],
    )
}

/// Run a strategy through a store-backed cache, like `dse sweep
/// --cache` does (fresh memory tier, shared disk tier).
fn sweep_with_store(
    strategy: &dyn SearchStrategy,
    space: &DesignSpace,
    store: &Arc<Store>,
) -> SweepResult {
    let cache = EvalCache::new().with_store(Arc::clone(store));
    let ctx = SweepContext::new(&cache, 2);
    strategy.run(space, &ctx).unwrap()
}

/// One record of the data file: (start, content_end, kind).  The
/// record's bytes are `start..content_end`; the newline terminator
/// sits at `content_end`.
fn record_spans(bytes: &[u8]) -> Vec<(usize, usize, String)> {
    let mut spans = Vec::new();
    let mut start = 0;
    for (i, b) in bytes.iter().enumerate() {
        if *b == b'\n' {
            let line = std::str::from_utf8(&bytes[start..i]).unwrap();
            let v = Json::parse(line).unwrap();
            let kind = v.field("record").unwrap().as_str().unwrap().to_string();
            spans.push((start, i, kind));
            start = i + 1;
        }
    }
    assert_eq!(start, bytes.len(), "store data must end with a newline");
    spans
}

fn assert_rows_bit_identical(a: &Evaluation, b: &Evaluation, tag: &str) {
    assert_eq!(a.workload, b.workload, "{tag}");
    assert_eq!(a.device, b.device, "{tag}");
    assert_eq!(a.design, b.design, "{tag}");
    assert_eq!(a.pe_depth, b.pe_depth, "{tag}");
    assert_eq!(a.resources.core, b.resources.core, "{tag}");
    assert_eq!(a.resources.total, b.resources.total, "{tag}");
    assert_eq!(a.timing.n_c, b.timing.n_c, "{tag}");
    assert_eq!(a.timing.total_cycles, b.timing.total_cycles, "{tag}");
    assert_eq!(
        a.timing.utilization.to_bits(),
        b.timing.utilization.to_bits(),
        "{tag}"
    );
    assert_eq!(a.power_w.to_bits(), b.power_w.to_bits(), "{tag}");
    assert_eq!(a.perf_per_watt.to_bits(), b.perf_per_watt.to_bits(), "{tag}");
    assert_eq!(a.infeasible, b.infeasible, "{tag}");
}

fn strategies() -> Vec<Box<dyn SearchStrategy>> {
    vec![
        Box::new(Exhaustive),
        Box::new(BoundedPrune::default()),
        Box::new(HillClimb { seed: 7, restarts: 2, max_steps: 16 }),
    ]
}

fn assert_results_identical(a: &SweepResult, b: &SweepResult, tag: &str) {
    assert_eq!(a.candidates, b.candidates, "{tag}: candidates");
    assert_eq!(a.skipped, b.skipped, "{tag}: skipped");
    assert_eq!(
        a.evaluated + a.cache_hits as usize,
        b.evaluated + b.cache_hits as usize,
        "{tag}: total evaluation touches"
    );
    assert_eq!(a.evals.len(), b.evals.len(), "{tag}: row count");
    for (i, (x, y)) in a.evals.iter().zip(&b.evals).enumerate() {
        assert_rows_bit_identical(x, y, &format!("{tag}, row {i}"));
    }
    let best =
        |r: &SweepResult| r.best().map(|e| (e.design, e.perf_per_watt.to_bits()));
    assert_eq!(best(a), best(b), "{tag}: best");
    let frontier = |r: &SweepResult| {
        let mut v: Vec<(u32, u32, &str)> = r
            .pareto()
            .iter()
            .map(|e| (e.design.n, e.design.m, e.device))
            .collect();
        v.sort();
        v
    };
    assert_eq!(frontier(a), frontier(b), "{tag}: pareto frontier");
}

/// Satellite 1: two threads with *independent* `Store` handles (no
/// shared in-process state — exactly two processes, minus the fork)
/// race overlapping appends over one `Global`-scoped directory.  The
/// lock file serializes them: afterwards the file holds exactly one
/// record per content address, every row bit-identical, none lost.
#[test]
fn concurrent_handles_race_appends_without_losing_or_duplicating_rows() {
    let space = small_space("lbm");
    let paths = {
        // resolve the Global scope through the env override, as two
        // `--cache global` processes sharing DSE_CACHE_DIR would
        let _env = env_lock();
        let dir = std::env::temp_dir()
            .join(format!("spdx_store_race_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::env::set_var(STORE_DIR_ENV, &dir);
        let paths = StorePaths::for_scope(StoreScope::Global).unwrap();
        std::env::remove_var(STORE_DIR_ENV);
        assert_eq!(paths.dir, dir);
        paths
    };

    // the rows both "processes" will produce: one uninterrupted sweep
    let cache = EvalCache::new();
    let ctx = SweepContext::new(&cache, 2);
    let reference = Exhaustive.run(&space, &ctx).unwrap();
    assert_eq!(reference.evals.len(), 8);

    // overlapping slices: rows 2..6 are contested
    let slices =
        [reference.evals[..6].to_vec(), reference.evals[2..].to_vec()];
    let handles: Vec<_> = slices
        .into_iter()
        .map(|rows| {
            let paths = paths.clone();
            let space = space.clone();
            std::thread::spawn(move || {
                let store = Store::open_at(paths, &space).unwrap();
                // row-at-a-time: one lock acquisition per append, the
                // worst case for interleaving
                for row in &rows {
                    store.append(row).unwrap();
                    // reads race the other handle's appends too
                    let key = key_for(&space, row.design.n, row.design.m);
                    let read = store.lookup(&key).expect("own append visible");
                    assert_rows_bit_identical(&read, row, "read-back");
                }
                store.stats()
            })
        })
        .collect();
    let stats: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // every address written exactly once across both handles: the
    // catch-up scan under the lock deduplicates the contested slice
    let appended: u64 = stats.iter().map(|s| s.appended).sum();
    assert_eq!(appended, 8, "each content address hits disk exactly once");
    assert!(!paths.lock.exists(), "lock file released");

    // the file itself: one header, eight row records, nothing else
    let bytes = std::fs::read(&paths.data).unwrap();
    let spans = record_spans(&bytes);
    assert_eq!(spans.iter().filter(|s| s.2 == "header").count(), 1);
    assert_eq!(spans.iter().filter(|s| s.2 == "row").count(), 8);
    assert_eq!(spans.len(), 9);

    // a third handle preloads all eight, bit-identical to the source
    let store = Store::open_at(paths.clone(), &space).unwrap();
    assert_eq!(store.stats().preloaded, 8);
    for row in &reference.evals {
        let key = key_for(&space, row.design.n, row.design.m);
        let got = store.lookup(&key).expect("no row lost");
        assert_rows_bit_identical(&got, row, "merged store");
    }
    cleanup(&paths);
}

/// Satellite 2a: the crash-injection property test, ported from the
/// journal.  Truncate the data file at **every** byte boundary: open
/// must keep exactly the records whose content is fully inside the
/// prefix (a record's own newline may be the casualty — its content
/// still parses), refuse prefixes that end before the header is
/// intact, and start fresh from an empty file.
#[test]
fn recovery_at_every_byte_boundary_keeps_the_intact_prefix() {
    let space = small_space("lbm");
    let seed_paths = tmp("boundary_seed");
    cleanup(&seed_paths);
    let store = Arc::new(Store::open_at(seed_paths.clone(), &space).unwrap());
    let reference = sweep_with_store(&Exhaustive, &space, &store);
    assert_eq!(reference.evals.len(), 8);
    let bytes = std::fs::read(&seed_paths.data).unwrap();
    cleanup(&seed_paths);

    let spans = record_spans(&bytes);
    assert_eq!(spans.first().unwrap().2, "header");
    assert_eq!(spans.iter().filter(|s| s.2 == "row").count(), 8);
    let header_content_end = spans[0].1;

    let by_design: std::collections::HashMap<(u32, u32), &Arc<Evaluation>> =
        reference.evals.iter().map(|e| ((e.design.n, e.design.m), e)).collect();
    let keys: Vec<((u32, u32), CacheKey)> = reference
        .evals
        .iter()
        .map(|e| {
            ((e.design.n, e.design.m), key_for(&space, e.design.n, e.design.m))
        })
        .collect();

    let cut_paths = tmp("boundary_cut");
    cleanup(&cut_paths);
    std::fs::create_dir_all(&cut_paths.dir).unwrap();
    for t in 0..=bytes.len() {
        std::fs::write(&cut_paths.data, &bytes[..t]).unwrap();
        let opened = Store::open_at(cut_paths.clone(), &space);
        if t > 0 && t < header_content_end {
            // only a torn fragment of the header: refuse, don't guess
            let err = opened.err().map(|e| e.to_string()).unwrap_or_else(|| {
                panic!("cut at {t}: a headerless store must be refused")
            });
            assert!(err.contains("no intact header"), "cut at {t}: {err}");
            continue;
        }
        let store = opened.unwrap_or_else(|e| panic!("cut at {t}: {e}"));
        let want = spans
            .iter()
            .filter(|(_, content_end, kind)| kind == "row" && *content_end <= t)
            .count();
        assert_eq!(store.stats().rows, want, "cut at {t}");
        let mut found = 0;
        for ((n, m), key) in &keys {
            if let Some(row) = store.lookup(key) {
                assert_rows_bit_identical(
                    &row,
                    by_design[&(*n, *m)],
                    &format!("cut at {t}, point ({n}, {m})"),
                );
                found += 1;
            }
        }
        assert_eq!(found, want, "cut at {t}: index and lookups agree");
    }
    cleanup(&cut_paths);
}

/// Satellite 2b: a torn tail (no trailing newline) is the *only*
/// malformation open repairs — it is truncated away and appends
/// continue cleanly after it.  Everything else mid-file is corruption
/// and refused by name, with the file left byte-identical.
#[test]
fn torn_tails_are_repaired_and_mid_file_corruption_is_refused() {
    let space = small_space("lbm");
    let paths = tmp("corrupt");
    cleanup(&paths);
    let store = Arc::new(Store::open_at(paths.clone(), &space).unwrap());
    let reference = sweep_with_store(&Exhaustive, &space, &store);
    drop(store);
    let good = std::fs::read(&paths.data).unwrap();
    let spans = record_spans(&good);
    let row_line = |i: usize| {
        let (s, e, _) = spans.iter().filter(|s| s.2 == "row").nth(i).unwrap();
        good[*s..*e + 1].to_vec()
    };

    // torn tail: unterminated garbage after the last record — repaired
    let mut torn = good.clone();
    torn.extend_from_slice(b"{\"record\":\"row\",\"finge");
    std::fs::write(&paths.data, &torn).unwrap();
    let store = Store::open_at(paths.clone(), &space).unwrap();
    assert_eq!(store.stats().rows, 8, "torn tail costs nothing");
    // ...and the repair truncated it, so appends go after good data
    assert_eq!(std::fs::read(&paths.data).unwrap(), good);
    assert_eq!(store.append_all(&reference.evals).unwrap(), 0);
    drop(store);

    // the same garbage *with* its newline is a real record that fails
    // to parse: corruption, named by byte offset
    let mut garbage = good.clone();
    garbage.extend_from_slice(b"{\"record\":\"row\",\"finge\n");
    std::fs::write(&paths.data, &garbage).unwrap();
    let err = Store::open_at(paths.clone(), &space).unwrap_err().to_string();
    assert!(err.contains("corrupt record at byte"), "{err}");
    assert_eq!(std::fs::read(&paths.data).unwrap(), garbage, "refusal destroys nothing");

    // garbage spliced *between* intact records: also corruption (the
    // torn-tail carve-out applies only to the final unterminated line)
    let mut spliced = Vec::new();
    spliced.extend_from_slice(&good[..spans[3].1 + 1]);
    spliced.extend_from_slice(b"!!not json!!\n");
    spliced.extend_from_slice(&good[spans[3].1 + 1..]);
    std::fs::write(&paths.data, &spliced).unwrap();
    let err = Store::open_at(paths.clone(), &space).unwrap_err().to_string();
    assert!(err.contains("corrupt record at byte"), "{err}");

    // an unknown record kind is a named refusal, not a skip: this
    // build cannot know whether it is safe to append after it
    let mut unknown = good.clone();
    unknown.extend_from_slice(b"{\"record\":\"frobnicate\"}\n");
    std::fs::write(&paths.data, &unknown).unwrap();
    let err = Store::open_at(paths.clone(), &space).unwrap_err().to_string();
    assert!(err.contains("unknown record"), "{err}");

    // a row before any header
    std::fs::write(&paths.data, row_line(0)).unwrap();
    let err = Store::open_at(paths.clone(), &space).unwrap_err().to_string();
    assert!(err.contains("before the header"), "{err}");

    // two headers
    let mut doubled = good.clone();
    doubled.extend_from_slice(&good[..spans[0].1 + 1]);
    std::fs::write(&paths.data, &doubled).unwrap();
    let err = Store::open_at(paths.clone(), &space).unwrap_err().to_string();
    assert!(err.contains("duplicate header"), "{err}");
    cleanup(&paths);
}

/// Satellite 2c: schema versions outside
/// `STORE_MIN_VERSION..=STORE_SCHEMA_VERSION` are refused with a named
/// error and the file is left byte-identical — a newer build's store
/// is never clobbered by an older one.
#[test]
fn mismatched_schema_versions_are_refused_without_destroying_data() {
    assert_eq!(STORE_SCHEMA_VERSION, 1, "bumping the schema is a conscious act: update this test and the README policy");
    let space = small_space("lbm");
    let paths = tmp("version");
    cleanup(&paths);
    std::fs::create_dir_all(&paths.dir).unwrap();
    for version in [0u64, 2, 99] {
        let file =
            format!("{{\"record\":\"header\",\"version\":{version}}}\n");
        std::fs::write(&paths.data, &file).unwrap();
        let err =
            Store::open_at(paths.clone(), &space).unwrap_err().to_string();
        assert!(
            err.contains(&format!("schema version {version}")),
            "version {version}: {err}"
        );
        assert_eq!(
            std::fs::read_to_string(&paths.data).unwrap(),
            file,
            "version {version}: refusal must not touch the file"
        );
        assert!(!paths.lock.exists(), "version {version}: lock released");
    }
    cleanup(&paths);
}

/// Satellite 2d: the store is an accelerator, not a correctness layer.
/// When the directory vanishes mid-run, the first failed write-through
/// degrades the handle to in-memory-only — gauge raised, sweep rows
/// intact, later appends free no-ops.
#[test]
fn vanished_store_degrades_to_in_memory_without_failing_the_sweep() {
    let space = small_space("lbm");
    let paths = tmp("degraded");
    cleanup(&paths);
    let store = Arc::new(Store::open_at(paths.clone(), &space).unwrap());
    assert!(!store.is_degraded());
    cleanup(&paths); // the rug pull: every append from here fails

    let obs = Obs::new();
    let cache = EvalCache::new().with_store(Arc::clone(&store));
    let ctx = SweepContext::new(&cache, 2).with_obs(&obs);
    let result = Exhaustive.run(&space, &ctx).unwrap();
    assert_eq!(result.evals.len(), 8, "the sweep survives the store");
    assert_eq!(result.evaluated, 8);
    assert!(store.is_degraded());
    assert!(store.stats().degraded);
    assert_eq!(obs.metrics.gauge("store.degraded").get(), 1);

    // degraded appends are silent no-ops, not repeated failures
    assert_eq!(store.append_all(&result.evals).unwrap(), 0);
    assert!(!paths.dir.exists(), "degraded handle recreates nothing");
}

/// Satellite 3: the equivalence property.  For every strategy × every
/// registered workload, a store-backed sweep is bit-identical to one
/// without a store, and a second cold process over the warm store
/// performs **zero** fresh evaluations — every unique point answered
/// from disk.
#[test]
fn store_backed_sweeps_are_bit_identical_and_the_second_process_is_all_hits() {
    for name in workload::names() {
        let space = small_space(name);
        for strategy in strategies() {
            let tag = format!("{name}/{}", strategy.name());
            let paths = tmp(&format!("equiv_{name}_{}", strategy.name()));
            cleanup(&paths);

            // the reference: no store anywhere
            let cache = EvalCache::new();
            let ctx = SweepContext::new(&cache, 2);
            let plain = strategy.run(&space, &ctx).unwrap();

            // first process: cold store, every fresh row written through
            let store = Arc::new(Store::open_at(paths.clone(), &space).unwrap());
            let first = sweep_with_store(&*strategy, &space, &store);
            assert_results_identical(&plain, &first, &tag);
            let s1 = store.stats();
            assert_eq!(s1.hits, 0, "{tag}: nothing to hit in a cold store");
            assert_eq!(
                s1.appended as usize, first.evaluated,
                "{tag}: every fresh evaluation persisted"
            );

            // second process: fresh memory, warm disk — recomputes nothing
            let store2 =
                Arc::new(Store::open_at(paths.clone(), &space).unwrap());
            assert_eq!(
                store2.stats().preloaded as usize,
                first.evals.len(),
                "{tag}: the whole sweep preloads"
            );
            let second = sweep_with_store(&*strategy, &space, &store2);
            assert_eq!(
                second.evaluated, 0,
                "{tag}: a warm store means zero fresh evaluations"
            );
            let s2 = store2.stats();
            assert_eq!(
                s2.hits as usize,
                second.evals.len(),
                "{tag}: every unique point answered from disk"
            );
            assert_eq!(s2.misses, 0, "{tag}");
            assert_eq!(s2.appended, 0, "{tag}: nothing new to write");
            assert_results_identical(&plain, &second, &tag);
            cleanup(&paths);
        }
    }
}

/// Satellite 4: quarantine × persistence.  A `FaultPlan`-panicked
/// point is quarantined as a `FailRow` and its identity never reaches
/// the store as a success; a fault-free retry (what `dse resume
/// --retry-failed` runs) supersedes the quarantine with a real row,
/// and a third process reads the whole space from disk.
#[test]
fn quarantined_points_are_never_persisted_until_a_retry_succeeds() {
    let space = small_space("lbm");
    let paths = tmp("fault");
    cleanup(&paths);
    let poisoned = key_for(&space, 2, 2);

    // the reference: same strategy, no faults, no store
    let cache = EvalCache::new();
    let clean =
        Exhaustive.run(&space, &SweepContext::new(&cache, 2)).unwrap();
    assert_eq!(clean.evals.len(), 8);

    // run 1: (2, 2) panics on every attempt → quarantined, not stored
    let plan = Arc::new(
        FaultPlan::new().with_fault(Fault::new(FaultKind::Panic).at_n(2).at_m(2)),
    );
    let sup = Supervisor::new()
        .with_retries(1)
        .with_backoff(Duration::ZERO)
        .with_faults(plan);
    let store = Arc::new(Store::open_at(paths.clone(), &space).unwrap());
    let cache = EvalCache::new().with_store(Arc::clone(&store));
    let ctx = SweepContext::new(&cache, 2).with_supervisor(&sup);
    let faulted = Exhaustive.run(&space, &ctx).unwrap();
    assert_eq!(faulted.failures.len(), 1);
    assert_eq!(
        (faulted.failures[0].design.n, faulted.failures[0].design.m),
        (2, 2)
    );
    assert_eq!(faulted.evals.len(), 7);
    assert_eq!(store.stats().appended, 7);
    drop(store);

    // the file holds successes only — and not the poisoned identity
    let bytes = std::fs::read(&paths.data).unwrap();
    let spans = record_spans(&bytes);
    assert_eq!(spans.iter().filter(|s| s.2 == "row").count(), 7);
    assert!(spans.iter().all(|s| s.2 == "row" || s.2 == "header"));
    let probe = Store::open_at(paths.clone(), &space).unwrap();
    assert_eq!(probe.stats().rows, 7);
    assert!(
        probe.lookup(&poisoned).is_none(),
        "a quarantined point must never appear as a success"
    );
    drop(probe);

    // run 2: the fault is gone — only the quarantined point is fresh,
    // and its success row supersedes the quarantine on disk
    let store2 = Arc::new(Store::open_at(paths.clone(), &space).unwrap());
    let retried = sweep_with_store(&Exhaustive, &space, &store2);
    assert!(retried.failures.is_empty());
    assert_eq!(retried.evaluated, 1, "only the poisoned point recomputes");
    assert_eq!(store2.stats().hits, 7);
    assert_eq!(store2.stats().appended, 1);
    assert_results_identical(&clean, &retried, "retry");
    drop(store2);

    // run 3: the whole space now comes from the store
    let store3 = Arc::new(Store::open_at(paths.clone(), &space).unwrap());
    assert_eq!(store3.stats().preloaded, 8);
    assert!(store3.lookup(&poisoned).is_some(), "the success superseded");
    let third = sweep_with_store(&Exhaustive, &space, &store3);
    assert_eq!(third.evaluated, 0);
    assert_results_identical(&clean, &third, "third run");
    cleanup(&paths);
}

/// The on-disk layout and scope resolution the README documents:
/// `store.ndjson` + `store.lock` inside the scope directory, `Local`
/// under `./.dse-cache`, `Global` overridable via `DSE_CACHE_DIR`.
#[test]
fn scope_layout_and_env_override_are_stable() {
    let p = StorePaths::in_dir("/scope/dir");
    assert_eq!(p.dir, Path::new("/scope/dir"));
    assert_eq!(p.data, Path::new("/scope/dir/store.ndjson"));
    assert_eq!(p.lock, Path::new("/scope/dir/store.lock"));
    assert_eq!(StoreScope::Local.dir().unwrap(), PathBuf::from(".dse-cache"));

    let _env = env_lock();
    let dir = std::env::temp_dir()
        .join(format!("spdx_store_scope_{}", std::process::id()));
    std::env::set_var(STORE_DIR_ENV, &dir);
    assert_eq!(StorePaths::for_scope(StoreScope::Global).unwrap().dir, dir);
    std::env::remove_var(STORE_DIR_ENV);
}
