//! Crash-injection harness for the append-only sweep journal.
//!
//! The journal's crash model is byte truncation: an append-only log
//! interrupted at any moment is a prefix of the uninterrupted log (plus
//! at most one torn tail record), so killing a sweep is simulated
//! exactly by cutting its journal at a byte boundary.  The harness
//! proves the two properties the journal exists for:
//!
//! 1. **recovery is exact** — for *every* truncation point of the
//!    file, `Journal::recover` returns precisely the rows whose
//!    records are intact in the prefix, bit-identically, and nothing
//!    else (`recovery_at_every_byte_boundary_is_the_intact_prefix`);
//! 2. **resume loses nothing** — a sweep interrupted mid-record and
//!    resumed from its journal produces a `SweepResult` (rows, best,
//!    Pareto frontier, counters) bit-identical to a sweep that never
//!    crashed, for every strategy and every registered workload
//!    (`interrupted_then_resumed_matches_uninterrupted`).
//!
//! Plus the `Session::merge` edge cases around journals: finalized ×
//! in-progress, duplicate coordinates, and mismatched space
//! fingerprints.

use std::path::{Path, PathBuf};

use spdx::dse::json::Json;
use spdx::dse::{
    BoundedPrune, DesignSpace, EvalCache, Exhaustive, HillClimb, Journal,
    JournalWriter, SearchStrategy, Session, SweepContext, SweepResult,
};
use spdx::resource::STRATIX_V_5SGXEA7;
use spdx::workload;

fn small_space(workload: &'static str) -> DesignSpace {
    DesignSpace {
        workload,
        grids: vec![(32, 16)],
        max_n: 2,
        max_m: 4,
        devices: vec![&STRATIX_V_5SGXEA7],
        ddr_variants: vec![Default::default()],
        passes: 2,
        latency: Default::default(),
    }
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("spdx_crash_{tag}_{}.jnl", std::process::id()))
}

/// Run a strategy with a journal sink on a fresh cache, finalizing the
/// journal like `dse sweep --journal` does.  `sync_every(1)` so every
/// row is on disk the moment it completes.
fn sweep_with_journal(
    strategy: &dyn SearchStrategy,
    space: &DesignSpace,
    path: &Path,
) -> SweepResult {
    let cache = EvalCache::new();
    let writer = JournalWriter::create(path, strategy.name(), space).unwrap().with_sync_every(1);
    let ctx = SweepContext::new(&cache, 2).with_sink(&writer);
    let result = strategy.run(space, &ctx).unwrap();
    writer.finalize(&result).unwrap();
    result
}

/// One record of a journal file: (start, content_end, kind).  The
/// record's bytes are `start..content_end`, the newline terminator sits
/// at `content_end`.
fn record_spans(bytes: &[u8]) -> Vec<(usize, usize, String)> {
    let mut spans = Vec::new();
    let mut start = 0;
    for (i, b) in bytes.iter().enumerate() {
        if *b == b'\n' {
            let line = std::str::from_utf8(&bytes[start..i]).unwrap();
            let v = Json::parse(line).unwrap();
            let kind = v.field("record").unwrap().as_str().unwrap().to_string();
            spans.push((start, i, kind));
            start = i + 1;
        }
    }
    assert_eq!(start, bytes.len(), "journal must end with a newline");
    spans
}

fn assert_rows_bit_identical(
    a: &spdx::explore::Evaluation,
    b: &spdx::explore::Evaluation,
    tag: &str,
) {
    assert_eq!(a.workload, b.workload, "{tag}");
    assert_eq!(a.device, b.device, "{tag}");
    assert_eq!(a.design, b.design, "{tag}");
    assert_eq!(a.pe_depth, b.pe_depth, "{tag}");
    assert_eq!(a.resources.core, b.resources.core, "{tag}");
    assert_eq!(a.resources.total, b.resources.total, "{tag}");
    assert_eq!(a.timing.n_c, b.timing.n_c, "{tag}");
    assert_eq!(a.timing.total_cycles, b.timing.total_cycles, "{tag}");
    assert_eq!(a.timing.utilization.to_bits(), b.timing.utilization.to_bits(), "{tag}");
    assert_eq!(a.power_w.to_bits(), b.power_w.to_bits(), "{tag}");
    assert_eq!(a.perf_per_watt.to_bits(), b.perf_per_watt.to_bits(), "{tag}");
    assert_eq!(a.infeasible, b.infeasible, "{tag}");
}

/// The crash-injection property test: truncate a finalized journal at
/// **every** byte boundary and check recovery returns exactly the rows
/// whose records are fully inside the prefix — the intact prefix of
/// the uninterrupted run, bit-identically — and errors before the
/// header is intact.
#[test]
fn recovery_at_every_byte_boundary_is_the_intact_prefix() {
    let space = small_space("lbm");
    let path = tmp("boundary_full");
    let result = sweep_with_journal(&Exhaustive, &space, &path);
    assert_eq!(result.evals.len(), 8);

    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let spans = record_spans(&bytes);
    assert_eq!(spans.first().unwrap().2, "header");
    assert_eq!(spans.last().unwrap().2, "finalize");
    assert_eq!(spans.iter().filter(|s| s.2 == "row").count(), 8);
    let header_end = spans[0].1;
    let finalize_end = spans.last().unwrap().1;

    let full = {
        let cut_path = tmp("boundary_ref");
        std::fs::write(&cut_path, &bytes).unwrap();
        let j = Journal::recover(&cut_path).unwrap();
        std::fs::remove_file(&cut_path).ok();
        j
    };
    assert_eq!(full.rows.len(), 8);
    assert!(full.complete());

    let cut_path = tmp("boundary_cut");
    for t in 0..=bytes.len() {
        std::fs::write(&cut_path, &bytes[..t]).unwrap();
        let recovered = Journal::recover(&cut_path);
        if t < header_end {
            assert!(recovered.is_err(), "cut at {t}: recovery must refuse a headerless log");
            continue;
        }
        let j = recovered.unwrap_or_else(|e| panic!("cut at {t}: {e}"));
        let want_rows = spans
            .iter()
            .filter(|(_, end, kind)| kind == "row" && *end <= t)
            .count();
        assert_eq!(j.rows.len(), want_rows, "cut at {t}");
        for (i, (a, b)) in j.rows.iter().zip(&full.rows).enumerate() {
            assert_rows_bit_identical(a, b, &format!("cut at {t}, row {i}"));
        }
        assert_eq!(
            j.complete(),
            finalize_end <= t,
            "cut at {t}: finalize record intact iff fully on disk"
        );
        assert!(j.intact_bytes as usize <= t, "cut at {t}");
    }
    std::fs::remove_file(&cut_path).ok();
}

fn strategies() -> Vec<Box<dyn SearchStrategy>> {
    vec![
        Box::new(Exhaustive),
        Box::new(BoundedPrune::default()),
        Box::new(HillClimb { seed: 7, restarts: 2, max_steps: 16 }),
    ]
}

fn assert_results_identical(a: &SweepResult, b: &SweepResult, tag: &str) {
    assert_eq!(a.candidates, b.candidates, "{tag}: candidates");
    assert_eq!(a.skipped, b.skipped, "{tag}: skipped");
    assert_eq!(
        a.evaluated + a.cache_hits as usize,
        b.evaluated + b.cache_hits as usize,
        "{tag}: total evaluation touches"
    );
    assert_eq!(a.evals.len(), b.evals.len(), "{tag}: row count");
    for (i, (x, y)) in a.evals.iter().zip(&b.evals).enumerate() {
        assert_rows_bit_identical(x, y, &format!("{tag}, row {i}"));
    }
    let best = |r: &SweepResult| {
        r.best().map(|e| (e.design, e.perf_per_watt.to_bits()))
    };
    assert_eq!(best(a), best(b), "{tag}: best");
    let frontier = |r: &SweepResult| {
        let mut v: Vec<(u32, u32, &str)> = r
            .pareto()
            .iter()
            .map(|e| (e.design.n, e.design.m, e.device))
            .collect();
        v.sort();
        v
    };
    assert_eq!(frontier(a), frontier(b), "{tag}: pareto frontier");
}

/// Keyed row set of a journal (journal row order is completion order,
/// which is scheduling-dependent — compare as sets).
fn row_keys(j: &Journal) -> Vec<(String, u32, u32, u64)> {
    let mut keys: Vec<(String, u32, u32, u64)> = Vec::new();
    for e in &j.rows {
        keys.push((
            format!("{}/{}", e.workload, e.device),
            e.design.n,
            e.design.m,
            e.perf_per_watt.to_bits(),
        ));
    }
    keys.sort();
    keys
}

/// The acceptance-criterion test: for every strategy and every
/// registered workload, a sweep interrupted mid-record (journal cut in
/// the middle of a row) and resumed from the recovered journal yields
/// a `SweepResult` bit-identical to the uninterrupted sweep, and the
/// resumed journal converges to the same row set, finalized.
#[test]
fn interrupted_then_resumed_matches_uninterrupted() {
    for name in workload::names() {
        let space = small_space(name);
        for strategy in strategies() {
            let tag = format!("{name}/{}", strategy.name());
            let path = tmp(&format!("resume_{name}_{}", strategy.name()));
            let uninterrupted = sweep_with_journal(&*strategy, &space, &path);
            let bytes = std::fs::read(&path).unwrap();
            let spans = record_spans(&bytes);
            let full = Journal::recover(&path).unwrap();
            assert!(full.complete(), "{tag}");
            assert!(!full.rows.is_empty(), "{tag}: journal must have rows");

            // crash: cut into the middle of a row record so recovery
            // must both drop a torn tail and keep the intact prefix
            let rows: Vec<&(usize, usize, String)> =
                spans.iter().filter(|s| s.2 == "row").collect();
            let mid = rows[rows.len() / 2];
            let cut = (mid.0 + mid.1) / 2;
            std::fs::write(&path, &bytes[..cut]).unwrap();

            let partial = Journal::recover(&path).unwrap_or_else(|e| panic!("{tag}: {e}"));
            assert!(partial.rows.len() < full.rows.len(), "{tag}");
            assert!(!partial.complete(), "{tag}");
            assert_eq!(partial.fingerprint, full.fingerprint, "{tag}");

            // resume: seed a fresh cache from the journaled rows, run
            // the same strategy, appending to the recovered journal
            let cache = EvalCache::new();
            let seeded = Session::from_journal(&partial).preload(&cache);
            assert_eq!(seeded, partial.rows.len(), "{tag}");
            let writer = JournalWriter::resume(&path, &partial).unwrap().with_sync_every(1);
            let ctx = SweepContext::new(&cache, 2).with_sink(&writer);
            let resumed = strategy.run(&space, &ctx).unwrap();
            writer.finalize(&resumed).unwrap();

            // journaled rows were answered from the cache, not redone
            assert!(
                resumed.cache_hits >= seeded as u64,
                "{tag}: every recovered row must be reused"
            );
            let touches = uninterrupted.evaluated + uninterrupted.cache_hits as usize;
            assert!(
                resumed.evaluated <= touches - seeded,
                "{tag}: resume recomputed a journaled row"
            );
            assert_results_identical(&uninterrupted, &resumed, &tag);

            // the journal converged: same row set as the full run,
            // finalized again
            let final_journal = Journal::recover(&path).unwrap();
            std::fs::remove_file(&path).ok();
            assert!(final_journal.complete(), "{tag}");
            assert_eq!(row_keys(&final_journal), row_keys(&full), "{tag}");
        }
    }
}

/// The fault-tolerance acceptance criterion: a sweep that quarantines
/// a panicking point (journaling the fail record) and is then resumed
/// with the fault removed converges to a `SweepResult` bit-identical
/// to a sweep that never faulted, for every strategy — and the journal
/// resolves the fail record in the fresh success row's favor.
#[test]
fn faulted_sweep_resumed_fault_free_converges_to_unfaulted() {
    use std::sync::Arc;
    use std::time::Duration;

    use spdx::coordinator::{Fault, FaultKind, FaultPlan, Supervisor};

    let space = small_space("lbm");
    for strategy in strategies() {
        let tag = strategy.name().to_string();
        // the reference: same strategy, no faults, fresh cache
        let cache = EvalCache::new();
        let ctx = SweepContext::new(&cache, 2);
        let clean = strategy.run(&space, &ctx).unwrap();

        // faulted run: (2, 2) panics on every attempt → quarantined
        // after the retry budget, journaled as a fail record
        let path = tmp(&format!("faulted_{tag}"));
        let plan =
            Arc::new(FaultPlan::new().with_fault(Fault::new(FaultKind::Panic).at_n(2).at_m(2)));
        let sup = Supervisor::new()
            .with_retries(1)
            .with_backoff(Duration::ZERO)
            .with_faults(plan);
        let cache = EvalCache::new();
        let writer =
            JournalWriter::create(&path, strategy.name(), &space).unwrap().with_sync_every(1);
        let ctx = SweepContext::new(&cache, 2).with_sink(&writer).with_supervisor(&sup);
        let faulted = strategy.run(&space, &ctx).unwrap();
        writer.finalize(&faulted).unwrap();
        // hill climb may simply not visit the poisoned point; when it
        // does, every strategy must survive and quarantine it
        assert!(faulted.failures.len() <= 1, "{tag}");
        assert_eq!(
            faulted.evals.len() + faulted.failures.len() + faulted.skipped,
            clean.evals.len() + clean.skipped,
            "{tag}: the quarantined point costs a row, not the run"
        );
        for f in &faulted.failures {
            assert_eq!((f.design.n, f.design.m), (2, 2), "{tag}");
            assert_eq!(f.attempts, 2, "{tag}: initial attempt + one retry");
        }

        // the journal carries the quarantine across the restart
        let partial = Journal::recover(&path).unwrap();
        assert!(partial.complete(), "{tag}: quarantine does not block finalize");
        assert_eq!(partial.failures.len(), faulted.failures.len(), "{tag}");
        assert_eq!(partial.rows.len(), faulted.evals.len(), "{tag}");

        // resume with the fault gone and nothing quarantined (what
        // `dse resume --retry-failed` builds): bit-identical to clean
        let cache = EvalCache::new();
        let seeded = Session::from_journal(&partial).preload(&cache);
        assert_eq!(seeded, partial.rows.len(), "{tag}");
        let writer = JournalWriter::resume(&path, &partial).unwrap().with_sync_every(1);
        let sup = Supervisor::new();
        let ctx = SweepContext::new(&cache, 2).with_sink(&writer).with_supervisor(&sup);
        let resumed = strategy.run(&space, &ctx).unwrap();
        writer.finalize(&resumed).unwrap();
        assert!(resumed.failures.is_empty(), "{tag}");
        assert_results_identical(&clean, &resumed, &tag);

        // the fresh success row resolved the journaled fail record
        let final_journal = Journal::recover(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(final_journal.complete(), "{tag}");
        assert!(final_journal.failures.is_empty(), "{tag}: fail resolved");
        assert_eq!(final_journal.rows.len(), clean.evals.len(), "{tag}");
    }
}

/// Satellite: `Session::merge` edge cases around journals.
#[test]
fn merge_of_finalized_and_in_progress_journals_dedupes() {
    let space = small_space("jacobi");
    let path = tmp("merge_full");
    sweep_with_journal(&Exhaustive, &space, &path);
    let bytes = std::fs::read(&path).unwrap();
    let full = Journal::recover(&path).unwrap();
    assert!(full.complete());

    // an in-progress copy: keep the header and the first few rows
    let spans = record_spans(&bytes);
    let rows: Vec<&(usize, usize, String)> = spans.iter().filter(|s| s.2 == "row").collect();
    let cut = rows[2].1 + 1; // three intact rows, no finalize
    let partial_path = tmp("merge_partial");
    std::fs::write(&partial_path, &bytes[..cut]).unwrap();
    let partial = Journal::recover(&partial_path).unwrap();
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&partial_path).ok();
    assert_eq!(partial.rows.len(), 3);
    assert!(!partial.complete());

    // finalized <- in-progress: duplicate coords across sessions must
    // not be unioned twice
    let mut merged = Session::from_journal(&full);
    merged.merge(&Session::from_journal(&partial)).unwrap();
    assert_eq!(merged.rows.len(), full.rows.len());

    // in-progress <- finalized: the partial session completes
    let mut grown = Session::from_journal(&partial);
    grown.merge(&Session::from_journal(&full)).unwrap();
    assert_eq!(grown.rows.len(), full.rows.len());
    let keyed = |rows: &[spdx::explore::Evaluation]| {
        let mut v: Vec<(u32, u32)> = rows.iter().map(|e| (e.design.n, e.design.m)).collect();
        v.sort();
        v
    };
    assert_eq!(keyed(&grown.rows), keyed(&full.rows));
}

/// Satellite: merging sessions over different spaces must error, not
/// silently union rows of sweeps nobody ran.
#[test]
fn merge_refuses_mismatched_space_fingerprints() {
    let base = small_space("lbm");
    let mut a = Session {
        strategy: "exhaustive".to_string(),
        params: Json::Obj(Vec::new()),
        space: base.clone(),
        rows: vec![],
        failures: vec![],
    };
    for other in [
        DesignSpace { grids: vec![(64, 32)], ..base.clone() },
        DesignSpace { max_m: 3, ..base.clone() },
        DesignSpace { passes: 9, ..base.clone() },
        small_space("jacobi"),
    ] {
        let b = Session {
            strategy: "exhaustive".to_string(),
            params: Json::Obj(Vec::new()),
            space: other,
            rows: vec![],
            failures: vec![],
        };
        let err = a.merge(&b).unwrap_err().to_string();
        assert!(err.contains("fingerprints differ"), "{err}");
    }
    // the identical space still merges
    let b = Session {
        strategy: "bounded-prune".to_string(),
        params: Json::Obj(Vec::new()),
        space: base,
        rows: vec![],
        failures: vec![],
    };
    a.merge(&b).unwrap();
}
