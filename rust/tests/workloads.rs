//! Integration tests for the stencil-workload subsystem: every
//! registered workload must drive the full (n, m) explorer, and each
//! compiled kernel must match its software reference.

use spdx::explore::{candidates, evaluate, explore, pareto, ExploreConfig};
use spdx::workload::{self, DesignPoint, WorkloadRunner};

fn small_cfg(workload: &'static str) -> ExploreConfig {
    ExploreConfig {
        workload,
        grid_w: 64,
        grid_h: 32,
        max_n: 2,
        max_m: 2,
        passes: 2,
        keep_infeasible: true,
        ..Default::default()
    }
}

#[test]
fn explore_ranks_every_registered_workload() {
    for wl in workload::all() {
        let cfg = small_cfg(wl.name());
        let evals = explore(&cfg).unwrap();
        assert_eq!(evals.len(), 4, "{}: 4 candidates (n,m in {{1,2}}^2)", wl.name());

        // at least one feasible design, feasible rows first
        let n_feasible = evals.iter().filter(|e| e.infeasible.is_none()).count();
        assert!(n_feasible > 0, "{}: no feasible design", wl.name());
        for pair in evals.windows(2) {
            let (a, b) = (&pair[0], &pair[1]);
            assert!(
                !(a.infeasible.is_some() && b.infeasible.is_none()),
                "{}: infeasible row ranked above feasible",
                wl.name()
            );
            if a.infeasible.is_none() && b.infeasible.is_none() {
                assert!(
                    a.perf_per_watt >= b.perf_per_watt,
                    "{}: ranking not sorted by perf/W",
                    wl.name()
                );
            }
        }

        // rows are consistent
        for e in &evals {
            assert_eq!(e.workload, wl.name());
            assert!(e.pe_depth > 0);
            assert!(e.power_w > 0.0);
            assert!(e.timing.performance_gflops > 0.0);
            assert!(e.timing.utilization > 0.0 && e.timing.utilization <= 1.0);
        }

        // pareto frontier: non-empty subset of feasible rows containing
        // the perf/W winner
        let p = pareto(&evals);
        assert!(!p.is_empty(), "{}: empty pareto set", wl.name());
        assert!(p.iter().all(|e| e.infeasible.is_none()));
        let best = evals.iter().find(|e| e.infeasible.is_none()).unwrap();
        assert!(
            p.iter().any(|e| e.design == best.design),
            "{}: perf/W winner dominated",
            wl.name()
        );
    }
}

#[test]
fn every_new_kernel_matches_its_reference() {
    // the acceptance check: compiled-sim output vs software reference
    // within f32 tolerance on a small grid, for lanes and cascades
    for name in ["jacobi", "wave", "blur"] {
        let wl = workload::get(name).unwrap();
        for (n, m) in [(1u32, 1u32), (2, 2)] {
            let runner = WorkloadRunner::new(wl, DesignPoint::new(n, m, 16, 12)).unwrap();
            let d = runner.verify(4).unwrap();
            assert!(d < 1e-6, "{name} x{n} m{m}: hw vs ref diff {d}");
        }
    }
}

#[test]
fn lbm_through_the_trait_reproduces_table3_ranking() {
    // the seed's headline: temporal (1,2) beats spatial (2,1) at equal
    // n*m — unchanged now that LBM runs through the workload trait
    let cfg = ExploreConfig { keep_infeasible: false, ..small_cfg("lbm") };
    let evals = explore(&cfg).unwrap();
    let pos = |n: u32, m: u32| {
        evals
            .iter()
            .position(|e| e.design.n == n && e.design.m == m)
            .unwrap()
    };
    assert!(pos(1, 2) < pos(2, 1), "temporal must rank above spatial");
    // and per-row numbers still look like the seed's
    let e = evaluate(&DesignPoint::new(1, 1, 64, 32), &cfg).unwrap();
    assert_eq!(e.resources.core.dsps, 48);
    assert!(e.timing.utilization > 0.9);
}

#[test]
fn workload_words_and_flops_flow_into_timing() {
    // the same (n, m, grid) point demands less bandwidth for a 2-word
    // kernel than for the 10-word LBM, and peaks at its own flop rate
    let d = DesignPoint::new(1, 1, 64, 32);
    let lbm = evaluate(&d, &small_cfg("lbm")).unwrap();
    let jac = evaluate(&d, &small_cfg("jacobi")).unwrap();
    assert!(jac.timing.demand_gbps < lbm.timing.demand_gbps / 4.0);
    assert!(jac.timing.peak_gflops < lbm.timing.peak_gflops);
    // jacobi peak = n*m*4 flops * 0.18 GHz
    assert!((jac.timing.peak_gflops - 4.0 * 0.18).abs() < 1e-9);
}

#[test]
fn candidates_skip_non_dividing_lane_counts() {
    // grid width 30: n=4 does not divide it, n=1/2 do
    let cfg = ExploreConfig { grid_w: 30, grid_h: 10, max_n: 4, max_m: 2, ..small_cfg("jacobi") };
    let c = candidates(&cfg);
    assert_eq!(c.len(), 4);
    assert!(c.iter().all(|d| d.n != 4));
    assert!(c.iter().all(|d| d.w == 30 && d.h == 10));
}

#[test]
fn candidates_generate_for_every_new_workload() {
    // every candidate the explorer proposes must actually generate and
    // compile for every new kernel (lane counts divide the grid width)
    for name in ["jacobi", "wave", "blur"] {
        let wl = workload::get(name).unwrap();
        let cfg = small_cfg(name);
        let c = candidates(&cfg);
        assert_eq!(c.len(), 4, "{name}");
        for d in c {
            assert_eq!(d.w % d.n, 0, "{name}: n must divide w");
            let g = wl.generate(&d, Default::default()).unwrap();
            assert!(g.pe_depth > 0, "{name} ({}, {})", d.n, d.m);
        }
    }
}

#[test]
fn candidates_with_max_m_one_are_spatial_only() {
    let cfg = ExploreConfig { grid_w: 64, grid_h: 16, max_n: 4, max_m: 1, ..small_cfg("blur") };
    let c = candidates(&cfg);
    assert_eq!(c.len(), 3); // n in {1, 2, 4}, m = 1
    assert!(c.iter().all(|d| d.m == 1));
    let evals = explore(&cfg).unwrap();
    assert_eq!(evals.len(), 3);
}

#[test]
fn cli_explore_flag_reaches_each_workload() {
    for name in workload::names() {
        let code = spdx::cli::run(vec![
            "explore".to_string(),
            "--workload".to_string(),
            name.to_string(),
            "--grid".to_string(),
            "64x32".to_string(),
            "--max-n".to_string(),
            "2".to_string(),
            "--max-m".to_string(),
            "2".to_string(),
            "--passes".to_string(),
            "2".to_string(),
            "--workers".to_string(),
            "2".to_string(),
        ])
        .unwrap();
        assert_eq!(code, 0, "explore --workload {name}");
    }
}

#[test]
fn cli_verify_covers_all_workloads_on_a_small_grid() {
    let code = spdx::cli::run(vec![
        "verify".to_string(),
        "--grid".to_string(),
        "16x12".to_string(),
        "--steps".to_string(),
        "4".to_string(),
    ])
    .unwrap();
    assert_eq!(code, 0, "verify (all workloads) failed");
}
