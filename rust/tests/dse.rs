//! Integration tests for the DSE engine: strategy equivalence,
//! cache-counter semantics, session resume, and multi-device sweeps.

use spdx::dse::{
    space_fingerprint, BoundedPrune, DesignSpace, EvalCache, Exhaustive, HillClimb,
    Journal, JournalWriter, SearchStrategy, Session, SweepContext, SweepResult,
};
use spdx::explore::ExploreConfig;
use spdx::resource::{Device, ARRIA_10_GX1150, STRATIX_V_5SGXEA7};
use spdx::workload;

fn small_space(workload: &'static str) -> DesignSpace {
    DesignSpace {
        workload,
        grids: vec![(32, 16)],
        max_n: 2,
        max_m: 4,
        devices: vec![&STRATIX_V_5SGXEA7],
        ddr_variants: vec![Default::default()],
        passes: 2,
        latency: Default::default(),
    }
}

fn run(strategy: &dyn SearchStrategy, space: &DesignSpace) -> SweepResult {
    let cache = EvalCache::new();
    let ctx = SweepContext::new(&cache, 2);
    strategy.run(space, &ctx).unwrap()
}

/// Designs on the Pareto frontier, as a sorted, comparable set.
fn frontier_set(r: &SweepResult) -> Vec<(u32, u32)> {
    let mut v: Vec<(u32, u32)> =
        r.pareto().iter().map(|e| (e.design.n, e.design.m)).collect();
    v.sort();
    v
}

/// A part whose ALM capacity sits just under the given total, with
/// every other resource unconstrained — making deep cascades provably
/// infeasible while keeping (1, 1) comfortably inside.
fn alm_capped_device(alm_cap: u64) -> &'static Device {
    Box::leak(Box::new(Device {
        name: "test-tiny",
        key: "test-tiny",
        alms: alm_cap,
        regs: u64::MAX,
        bram_bits: u64::MAX,
        dsps: u64::MAX,
    }))
}

/// The satellite property test: for every registered workload,
/// `BoundedPrune` returns the same Pareto frontier (and the same
/// perf/W winner) as `Exhaustive`, while performing strictly fewer
/// `evaluate` computations.
///
/// The space is made prunable by construction: an ALM-capped device is
/// derived from the workload's own (1, 3) resource total, so cascades
/// of depth >= 3 are infeasible for *every* kernel — pruning territory
/// that exists regardless of the kernel's DSP/ALM mix.
#[test]
fn bounded_prune_matches_exhaustive_for_every_workload() {
    for name in workload::names() {
        // 1. survey the space on the reference part to pick a capacity
        let survey = run(&Exhaustive, &small_space(name));
        assert_eq!(survey.candidates, 8, "{name}: 2 widths x 4 cascade lengths");
        let at = |n: u32, m: u32| {
            survey
                .evals
                .iter()
                .find(|e| e.design.n == n && e.design.m == m)
                .unwrap_or_else(|| panic!("{name}: missing ({n}, {m})"))
        };
        // fitting pressure is normalized by the device's ALM count, so
        // on the smaller capped part every design only grows — (1, 3)
        // and everything deeper is infeasible with certainty
        let cap = at(1, 3).resources.total.alms - 1;
        assert!(at(1, 1).resources.total.alms < cap, "{name}: (1,1) must fit");
        let tiny = alm_capped_device(cap);
        let space = DesignSpace { devices: vec![tiny], ..small_space(name) };

        // 2. both strategies on the capped part, separate caches
        let ex = run(&Exhaustive, &space);
        let pr = run(&BoundedPrune::default(), &space);

        assert_eq!(ex.evaluated, 8, "{name}: exhaustive evaluates everything");
        assert!(
            pr.evaluated < ex.evaluated,
            "{name}: prune must evaluate strictly fewer points \
             ({} vs {})",
            pr.evaluated,
            ex.evaluated
        );
        assert!(pr.skipped >= 1, "{name}: something must be pruned");
        assert_eq!(
            pr.evaluated + pr.skipped,
            pr.candidates,
            "{name}: every candidate is either evaluated or skipped"
        );

        // 3. identical conclusions
        let (ex_best, pr_best) = (
            ex.best().unwrap_or_else(|| panic!("{name}: no feasible best")),
            pr.best().unwrap_or_else(|| panic!("{name}: no feasible best")),
        );
        assert_eq!(
            ex_best.design, pr_best.design,
            "{name}: perf/W winner must match"
        );
        assert_eq!(
            ex_best.perf_per_watt.to_bits(),
            pr_best.perf_per_watt.to_bits(),
            "{name}: winner metrics must be identical"
        );
        assert_eq!(
            frontier_set(&ex),
            frontier_set(&pr),
            "{name}: Pareto frontiers must match"
        );
        // everything pruning removed was genuinely infeasible
        let feasible_ex =
            ex.evals.iter().filter(|e| e.infeasible.is_none()).count();
        let feasible_pr =
            pr.evals.iter().filter(|e| e.infeasible.is_none()).count();
        assert_eq!(feasible_ex, feasible_pr, "{name}: feasible sets must match");
    }
}

/// The acceptance-criterion cache test: a repeated sweep through a
/// shared `EvalCache` reports hits and recomputes nothing.
#[test]
fn repeated_sweep_hits_cache_and_recomputes_nothing() {
    let space = small_space("lbm");
    let cache = EvalCache::new();
    let ctx = SweepContext::new(&cache, 2);

    let cold = Exhaustive.run(&space, &ctx).unwrap();
    let s1 = cache.stats();
    assert_eq!(cold.evaluated, 8);
    assert_eq!(cold.cache_hits, 0);
    assert_eq!((s1.misses, s1.hits, s1.entries), (8, 0, 8));

    let warm = Exhaustive.run(&space, &ctx).unwrap();
    let s2 = cache.stats();
    assert_eq!(warm.evaluated, 0, "warm sweep must recompute nothing");
    assert_eq!(warm.cache_hits, 8, "warm sweep must be answered by the cache");
    assert_eq!(s2.misses, s1.misses, "miss counter must not move");
    assert_eq!(s2.entries, 8);

    // bit-identical rows in both sweeps
    assert_eq!(cold.evals.len(), warm.evals.len());
    for (a, b) in cold.evals.iter().zip(&warm.evals) {
        assert_eq!(a.design, b.design);
        assert_eq!(a.perf_per_watt.to_bits(), b.perf_per_watt.to_bits());
        assert_eq!(a.power_w.to_bits(), b.power_w.to_bits());
        assert_eq!(a.resources.core, b.resources.core);
    }
}

/// The cache is shared *across* strategies: a prune sweep after an
/// exhaustive sweep is a pure cache walk.
#[test]
fn cache_is_shared_across_strategies() {
    let space = small_space("jacobi");
    let cache = EvalCache::new();
    let ctx = SweepContext::new(&cache, 2);
    let ex = Exhaustive.run(&space, &ctx).unwrap();
    assert!(ex.evaluated > 0);
    let pr = BoundedPrune::default().run(&space, &ctx).unwrap();
    assert_eq!(pr.evaluated, 0, "prune after exhaustive recomputes nothing");
    assert!(pr.cache_hits > 0);
}

/// Session files round-trip a sweep: save, load, preload, resume —
/// the resumed sweep is answered entirely from the session.
#[test]
fn session_resume_recomputes_nothing() {
    let space = small_space("wave");
    let cache = EvalCache::new();
    let ctx = SweepContext::new(&cache, 2);
    let first = Exhaustive.run(&space, &ctx).unwrap();
    assert_eq!(first.evaluated, 8);

    let path = std::env::temp_dir().join(format!(
        "spdx_dse_session_test_{}.json",
        std::process::id()
    ));
    Session::from_sweep(&first, &space).save(&path).unwrap();

    let loaded = Session::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.rows.len(), 8);
    assert_eq!(loaded.strategy, "exhaustive");
    // the session records the space it swept (resume re-sweeps it)
    assert_eq!(loaded.space.workload, "wave");
    assert_eq!(loaded.space.grids, vec![(32, 16)]);
    assert_eq!(loaded.space.max_m, 4);

    let cache2 = EvalCache::new();
    assert_eq!(loaded.preload(&cache2), 8);
    let ctx2 = SweepContext::new(&cache2, 2);
    let resumed = Exhaustive.run(&space, &ctx2).unwrap();
    assert_eq!(resumed.evaluated, 0, "resume must recompute nothing");
    assert_eq!(resumed.cache_hits, 8);
    for (a, b) in first.evals.iter().zip(&resumed.evals) {
        assert_eq!(a.design, b.design);
        assert_eq!(a.perf_per_watt.to_bits(), b.perf_per_watt.to_bits());
        assert_eq!(a.timing.utilization.to_bits(), b.timing.utilization.to_bits());
    }
}

/// On a single-column space the perf/W surface is unimodal along the
/// cascade axis, so a greedy walk must end at the exhaustive winner.
#[test]
fn hill_climb_finds_the_winner_on_a_cascade_column() {
    let space = DesignSpace { max_n: 1, ..small_space("lbm") };
    let ex = run(&Exhaustive, &space);
    for seed in [1u64, 42, 9000] {
        let hc = run(&HillClimb { seed, restarts: 1, max_steps: 16 }, &space);
        let (eb, hb) = (ex.best().unwrap(), hc.best().unwrap());
        assert_eq!(eb.design, hb.design, "seed {seed}");
        assert!(hc.evals.len() <= hc.candidates);
        assert_eq!(hc.evals.len() + hc.skipped, hc.candidates, "seed {seed}");
    }
}

/// Satellite: `HillClimb` determinism under resume — a restart on a
/// cache warmed from a previous run's rows must walk the same path and
/// report the same best point as the cold run, recomputing nothing.
#[test]
fn hill_climb_is_deterministic_under_resume() {
    let space = small_space("lbm");
    let hc = HillClimb { seed: 42, restarts: 2, max_steps: 16 };
    let cache = EvalCache::new();
    let cold = hc.run(&space, &SweepContext::new(&cache, 2)).unwrap();
    assert!(cold.evaluated > 0);
    let cold_best = cold.best().expect("a feasible best");

    let cache2 = EvalCache::new();
    Session::from_sweep(&cold, &space).preload(&cache2);
    let warm = hc.run(&space, &SweepContext::new(&cache2, 2)).unwrap();
    assert_eq!(warm.evaluated, 0, "warm restart must recompute nothing");
    assert!(warm.cache_hits > 0);
    let warm_best = warm.best().expect("a feasible best");
    assert_eq!(cold_best.design, warm_best.design);
    assert_eq!(cold_best.perf_per_watt.to_bits(), warm_best.perf_per_watt.to_bits());
    assert_eq!(cold.evals.len(), warm.evals.len());
    assert_eq!(cold.skipped, warm.skipped);
    for (a, b) in cold.evals.iter().zip(&warm.evals) {
        assert_eq!(a.design, b.design);
        assert_eq!(a.perf_per_watt.to_bits(), b.perf_per_watt.to_bits());
    }
}

/// Extends the empty-space regression to the journal: a journaled
/// sweep of an empty space is just a header and a finalize record, and
/// recovery reproduces the (empty) space faithfully.
#[test]
fn empty_space_sweeps_journal_cleanly() {
    let space = DesignSpace { devices: vec![], ..small_space("lbm") };
    let path = std::env::temp_dir().join(format!(
        "spdx_dse_empty_journal_{}.jnl",
        std::process::id()
    ));
    let cache = EvalCache::new();
    let writer = JournalWriter::create(&path, "hill-climb", &space).unwrap();
    let r = HillClimb::default()
        .run(&space, &SweepContext::new(&cache, 1).with_sink(&writer))
        .unwrap();
    assert_eq!(r.candidates, 0);
    writer.finalize(&r).unwrap();

    let j = Journal::recover(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(j.complete());
    assert!(j.rows.is_empty());
    assert_eq!(j.space.devices.len(), 0);
    assert_eq!(j.fingerprint, space_fingerprint(&space));
    assert_eq!(j.finalized.unwrap().candidates, 0);
}

/// Multi-device sweep: the same design space judged on two parts —
/// the bigger part keeps designs the Stratix V rejects.
#[test]
fn multi_device_space_widens_the_feasible_set() {
    let space = DesignSpace {
        workload: "lbm",
        grids: vec![(64, 32)],
        max_n: 2,
        max_m: 3,
        devices: vec![&STRATIX_V_5SGXEA7, &ARRIA_10_GX1150],
        ddr_variants: vec![Default::default()],
        passes: 2,
        latency: Default::default(),
    };
    let r = run(&Exhaustive, &space);
    assert_eq!(r.candidates, 12, "6 lattice points x 2 devices");
    let feasible_on = |dev: &str| {
        r.evals
            .iter()
            .filter(|e| e.device == dev && e.infeasible.is_none())
            .count()
    };
    let stratix = feasible_on("Stratix V 5SGXEA7");
    let arria = feasible_on("Arria 10 GX1150");
    // (2, 3) = six pipelines: over the Stratix V (288 DSPs, ~250k
    // ALMs), inside the Arria 10
    assert!(arria > stratix, "arria {arria} vs stratix {stratix}");
    assert_eq!(arria, 6, "every lattice point fits the Arria 10");

    // per-device winners exist and are reported per device
    for dev in ["Stratix V 5SGXEA7", "Arria 10 GX1150"] {
        assert!(
            r.evals.iter().any(|e| e.device == dev && e.infeasible.is_none()),
            "{dev}: no feasible design"
        );
    }
}

/// `explore::explore` must behave exactly like the exhaustive strategy
/// on the equivalent single-device space (it is now a wrapper).
#[test]
fn explore_is_a_thin_wrapper_over_exhaustive() {
    let cfg = ExploreConfig {
        workload: "blur",
        grid_w: 32,
        grid_h: 16,
        max_n: 2,
        max_m: 2,
        passes: 2,
        keep_infeasible: true,
        ..Default::default()
    };
    let via_explore = spdx::explore::explore(&cfg).unwrap();
    let via_dse = run(&Exhaustive, &DesignSpace::from_explore(&cfg));
    assert_eq!(via_explore.len(), via_dse.evals.len());
    for (a, b) in via_explore.iter().zip(&via_dse.evals) {
        assert_eq!(a.design, b.design);
        assert_eq!(a.perf_per_watt.to_bits(), b.perf_per_watt.to_bits());
    }
}
