//! Integration tests across the full stack: SPD text -> compiler ->
//! simulators -> models -> (optionally) the PJRT oracle.

use std::collections::HashMap;

use spdx::dfg;
use spdx::explore::{evaluate, ExploreConfig};
use spdx::lbm::reference::{self, LbmState};
use spdx::lbm::workload::{fluid_max_diff, LbmRunner};
use spdx::lbm::LbmDesign;
use spdx::power::PAPER_TABLE3;
use spdx::sim::{DataflowInput, Engine};
use spdx::spd::Registry;

/// A hand-written SPD program (not LBM): a 1-D three-point stencil
/// smoother with a comparator-gated bypass, exercising Trans2D,
/// comparators, muxes and EQU arithmetic together.
const SMOOTHER: &str = r#"
    Name smoother;
    Main_In {i::x, gate};
    Main_Out {o::y};
    Param third = 0.333333333;
    HDL T, 10, (c, l, r) = Trans2D(x), 8, 1, 0,0, -1,0, 1,0;
    EQU Nsum, s = (l + c + r) * third;
    HDL G, 1, (pass) = CompEq(gate), 1.0;
    HDL M, 1, (y) = SyncMux(pass, s, c);
"#;

#[test]
fn smoother_compiles_and_runs_both_engines() {
    let mut reg = Registry::with_library();
    let core = reg.register_source(SMOOTHER).unwrap();
    let c = dfg::compile(&core, &reg).unwrap();
    assert_eq!(c.graph.census().add, 2);
    assert_eq!(c.graph.census().mul, 1);

    let xs: Vec<f32> = (0..16).map(|i| (i % 5) as f32).collect();
    let gate: Vec<f32> = (0..16).map(|i| (i % 2) as f32).collect();
    let streams: HashMap<String, Vec<f32>> = [
        ("x".to_string(), xs.clone()),
        ("gate".to_string(), gate.clone()),
    ]
    .into_iter()
    .collect();

    let want = spdx::sim::run_dataflow(
        &c.graph,
        &DataflowInput { streams: &streams, regs: &HashMap::new() },
    )
    .unwrap();
    let mut engine = Engine::new(&c.graph, &c.schedule).unwrap();
    let got = engine.run_frame(&streams).unwrap();
    assert_eq!(got["y"], want["y"]);

    // spot-check semantics: gated cells are smoothed, others pass through
    for t in 1..15 {
        let smoothed = (xs[t - 1] + xs[t] + xs[t + 1]) * 0.333333333f32;
        let expect = if gate[t] == 1.0 { smoothed } else { xs[t] };
        assert!((got["y"][t] - expect).abs() < 1e-6, "t={t}");
    }
}

#[test]
fn lbm_x2_m2_matches_reference_through_cycle_engine() {
    // the hardest configuration for the engines: lanes AND cascade
    let runner = LbmRunner::new(LbmDesign::new(2, 2, 16, 8)).unwrap();
    let s0 = LbmState::cavity(8, 16);
    let (cy, _) = runner.run_cycle_accurate(s0.clone(), 1.25, 4).unwrap();
    let sw = reference::run(s0, 1.25, 4);
    let d = fluid_max_diff(&cy, &sw);
    assert!(d < 1e-5, "x2 m2 cycle-accurate vs reference: {d}");
}

#[test]
fn lbm_x4_lanes_cycle_engine() {
    let runner = LbmRunner::new(LbmDesign::new(4, 1, 16, 8)).unwrap();
    let s0 = LbmState::cavity(8, 16);
    let (cy, _) = runner.run_cycle_accurate(s0.clone(), 1.0 / 0.7, 3).unwrap();
    let df = runner.run_dataflow(s0, 1.0 / 0.7, 3).unwrap();
    assert!(fluid_max_diff(&cy, &df) < 1e-7);
}

#[test]
fn table3_reproduction_within_bands() {
    // the headline integration check: every Table III row within the
    // documented tolerance bands (EXPERIMENTS.md)
    let cfg = ExploreConfig { passes: 2, ..Default::default() };
    for p in &PAPER_TABLE3 {
        let e = evaluate(&LbmDesign::new(p.n, p.m, 720, 300), &cfg).unwrap();
        let rel = |ours: f64, paper: f64| (ours - paper).abs() / paper;
        assert!(rel(e.resources.core.alms as f64, p.alms) < 0.06, "({},{}) ALM", p.n, p.m);
        assert!(rel(e.resources.core.regs as f64, p.regs) < 0.01, "({},{}) Regs", p.n, p.m);
        assert!(
            rel(e.resources.core.bram_bits as f64, p.bram_bits) < 0.09,
            "({},{}) BRAM",
            p.n,
            p.m
        );
        assert_eq!(e.resources.core.dsps, p.dsps as u64, "({},{}) DSP", p.n, p.m);
        assert!(rel(e.timing.utilization, p.utilization) < 0.01, "({},{}) u", p.n, p.m);
        assert!(
            rel(e.timing.performance_gflops, p.performance_gflops) < 0.02,
            "({},{}) GF",
            p.n,
            p.m
        );
        assert!(rel(e.power_w, p.power_w) < 0.06, "({},{}) W", p.n, p.m);
    }
}

#[test]
fn verilog_roundtrip_structure() {
    // emitted netlist structurally matches the scheduled graph
    let mut reg = Registry::with_library();
    let core = reg.register_source(SMOOTHER).unwrap();
    let c = dfg::compile(&core, &reg).unwrap();
    let v = spdx::verilog::emit(&c.graph, &c.schedule).unwrap();
    assert!(v.contains("module smoother ("));
    assert_eq!(v.matches("spd_trans2d").count(), 1);
    assert_eq!(v.matches("spd_cmpeq").count(), 1);
    assert_eq!(v.matches("spd_mux").count(), 1);
    assert_eq!(v.matches("\n  fp_").count(), 3); // 2 adds + 1 mul
}

#[test]
fn cli_compile_and_table4_smoke() {
    // drive the CLI entry points directly
    let dir = std::env::temp_dir().join("spdx_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("smoother.spd");
    std::fs::write(&path, SMOOTHER).unwrap();
    let code = spdx::cli::run(vec![
        "compile".to_string(),
        path.to_string_lossy().to_string(),
    ])
    .unwrap();
    assert_eq!(code, 0);
    let code = spdx::cli::run(vec!["table4".to_string()]).unwrap();
    assert_eq!(code, 0);
    let code = spdx::cli::run(vec!["bogus-subcommand".to_string()]).unwrap();
    assert_eq!(code, 2);
}

#[test]
fn pjrt_oracle_agrees_with_compiled_hardware() {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the `pjrt` feature (stub runtime)");
        return;
    }
    let artifacts =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("lbm_step_32x32.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut rt = spdx::runtime::PjrtRuntime::new(&artifacts).unwrap();
    let runner = LbmRunner::new(LbmDesign::new(1, 1, 32, 32)).unwrap();
    let s0 = LbmState::cavity(32, 32);
    let one_tau = 1.0 / 0.6f32;

    let hw = runner.run_dataflow(s0.clone(), one_tau, 10).unwrap();
    let (f, attr) = spdx::runtime::state_to_dense(&s0);
    let out = rt
        .run_lbm("lbm_cascade10_32x32", &f, &attr, one_tau, 32, 32)
        .unwrap();
    let oracle = spdx::runtime::dense_to_state(&out, &s0);
    let d = fluid_max_diff(&hw, &oracle);
    assert!(d < 1e-5, "hardware vs PJRT oracle: {d}");
}

#[test]
fn taylor_green_periodic_physics() {
    // periodic Taylor-Green vortex through the rust reference: kinetic
    // energy decays exponentially at the analytic rate (validates the
    // LBM math itself, independent of implementation comparisons)
    let h = 32usize;
    let w = 32usize;
    let tau = 0.8f32;
    let one_tau = 1.0 / tau;
    let nu = (tau - 0.5) / 3.0;
    let mut state = LbmState::periodic(h, w);
    // superpose the TG velocity at equilibrium
    let u0 = 0.02f32;
    for y in 0..h {
        for x in 0..w {
            let kx = 2.0 * std::f32::consts::PI / w as f32;
            let ky = 2.0 * std::f32::consts::PI / h as f32;
            let ux = u0 * (kx * x as f32).cos() * (ky * y as f32).sin();
            let uy = -u0 * (kx * x as f32).sin() * (ky * y as f32).cos();
            let usq = ux * ux + uy * uy;
            for i in 0..9 {
                let eu = spdx::lbm::EX[i] as f32 * ux + spdx::lbm::EY[i] as f32 * uy;
                let feq = spdx::lbm::W[i] as f32
                    * (1.0 + 3.0 * eu + 4.5 * eu * eu - 1.5 * usq);
                state.f[i][y * w + x] = feq;
            }
        }
    }
    let ke = |s: &LbmState| -> f64 {
        (0..s.cells())
            .map(|idx| {
                let (rho, ux, uy) = s.macros(idx);
                (rho * (ux * ux + uy * uy)) as f64
            })
            .sum()
    };
    let e0 = ke(&state);
    let steps = 200;
    for _ in 0..steps {
        state = reference::step(&state, one_tau, 0.0, 0.0);
    }
    let e1 = ke(&state);
    let k2 = 2.0 * (2.0 * std::f64::consts::PI / w as f64).powi(2);
    let expected = e0 * (-2.0 * nu as f64 * k2 * steps as f64).exp();
    let rel = (e1 - expected).abs() / expected;
    assert!(rel < 0.05, "TG decay: {e1} vs analytic {expected} ({rel:.3})");
}

#[test]
fn explorer_matches_paper_narrative_on_reduced_grid() {
    // cheap sanity on a small grid: temporal beats spatial, u ranking
    let cfg = ExploreConfig {
        grid_w: 96,
        grid_h: 48,
        max_n: 2,
        max_m: 2,
        passes: 2,
        ..Default::default()
    };
    let evals = spdx::explore::explore(&cfg).unwrap();
    let get = |n: u32, m: u32| {
        evals
            .iter()
            .find(|e| e.design.n == n && e.design.m == m)
            .unwrap()
    };
    assert!(get(1, 2).perf_per_watt > get(2, 1).perf_per_watt);
    assert!(get(1, 2).timing.utilization > get(2, 1).timing.utilization);
}
