//! Integration tests for the sweep telemetry subsystem: the metrics
//! registry must reconcile exactly with the sweep's own counters (no
//! double counting, no dropped rows), the trace file must be
//! well-formed Chrome `trace_event` JSON, instrumentation must never
//! change sweep results, the live scrape endpoint must stay consistent
//! under concurrent readers, the stall watchdog must flag a hung
//! evaluation exactly once, and the NDJSON event log must reconcile
//! with the sweep that wrote it — including on the error path.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use spdx::dse::json::Json;
use spdx::dse::{
    space_fingerprint, BoundedPrune, DesignSpace, EvalCache, Exhaustive,
    HillClimb, JournalWriter, SearchStrategy, SweepContext,
};
use spdx::explore::ExploreConfig;
use spdx::obs::events::parse_event_log;
use spdx::obs::serve::{scan_once, StatusFn};
use spdx::obs::{EventLog, Obs, ObsServer, TraceSink, Watchdog};
use spdx::report::{status_json, SweepIdentity};

fn small_space() -> DesignSpace {
    DesignSpace::from_explore(&ExploreConfig {
        grid_w: 64,
        grid_h: 32,
        max_n: 2,
        max_m: 2,
        passes: 2,
        ..Default::default()
    })
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("spdx_obs_{tag}_{}.tmp", std::process::id()))
}

fn strategies() -> Vec<Box<dyn SearchStrategy>> {
    vec![
        Box::new(Exhaustive),
        Box::new(BoundedPrune::default()),
        Box::new(HillClimb { seed: 7, restarts: 2, max_steps: 8 }),
    ]
}

/// The registry's totals must equal the `SweepResult` counters and the
/// journal's row count exactly, for every strategy — the telemetry is
/// a view of the sweep, not an estimate of it.
#[test]
fn metrics_reconcile_with_sweep_result_for_all_strategies() {
    let space = small_space();
    for strategy in strategies() {
        let name = strategy.name();
        let path = tmp(&format!("reconcile_{name}"));
        let obs = Arc::new(Obs::new());
        let cache = EvalCache::new();
        let writer = JournalWriter::create(&path, name, &space)
            .unwrap()
            .with_sync_every(1)
            .with_obs(obs.clone());
        let ctx = SweepContext::new(&cache, 2).with_sink(&writer).with_obs(&obs);
        let r = strategy.run(&space, &ctx).unwrap();
        writer.finalize(&r).unwrap();

        let count = |metric: &str| obs.metrics.counter(metric).get();
        assert_eq!(count("sweep.evaluated"), r.evaluated as u64, "{name}");
        assert_eq!(count("sweep.cache_hits"), r.cache_hits, "{name}");
        assert_eq!(count("sweep.skipped"), r.skipped as u64, "{name}");
        assert_eq!(
            count("sweep.rows"),
            r.evaluated as u64 + r.cache_hits,
            "{name}: every completed row is counted exactly once"
        );
        assert_eq!(count("sweep.errors"), 0, "{name}");

        // the journal deduplicates, so its rows are the distinct
        // evaluations — exactly the result's eval list
        assert_eq!(writer.rows_written(), r.evals.len() as u64, "{name}");
        assert!(writer.fsyncs() >= 1 + r.evals.len() as u64, "{name}");

        // cache: every real evaluation was a miss, and the per-shard
        // counters sum to the totals
        let total = cache.stats();
        assert_eq!(total.misses, r.evaluated as u64, "{name}");
        let shards = cache.shard_stats();
        assert_eq!(shards.iter().map(|s| s.hits).sum::<u64>(), total.hits);
        assert_eq!(shards.iter().map(|s| s.misses).sum::<u64>(), total.misses);
        assert_eq!(shards.iter().map(|s| s.entries).sum::<usize>(), total.entries);

        // latency histograms: one sample per real evaluation in the
        // total and in each phase (cache hits must not pollute them)
        assert_eq!(obs.eval_stats().count, r.evaluated as u64, "{name}");
        for (phase, st) in obs.phase_stats() {
            assert_eq!(st.count, r.evaluated as u64, "{name}/{phase}");
            assert!(st.p50 <= st.p95 && st.p95 <= st.max, "{name}/{phase}");
        }

        // per-strategy coverage identity over the whole space
        match name {
            "exhaustive" | "bounded-prune" => {
                assert_eq!(r.evaluated + r.skipped, r.candidates, "{name}");
                assert_eq!(r.cache_hits, 0, "{name}: fresh cache");
            }
            _ => assert_eq!(r.evals.len() + r.skipped, r.candidates, "{name}"),
        }
        std::fs::remove_file(&path).ok();
    }
}

/// The trace must parse as one JSON array, every event line must be a
/// complete object with pid/tid/ts, and every `B` must have a matching
/// `E` on the same track, in order.
#[test]
fn trace_file_is_well_formed_chrome_json() {
    let space = small_space();
    let trace_path = tmp("trace");
    let jnl_path = tmp("trace_jnl");
    let obs =
        Arc::new(Obs::new().with_trace(TraceSink::create(&trace_path).unwrap()));
    let cache = EvalCache::new();
    let writer = JournalWriter::create(&jnl_path, "bounded-prune", &space)
        .unwrap()
        .with_sync_every(1)
        .with_obs(obs.clone());
    let ctx = SweepContext::new(&cache, 2).with_sink(&writer).with_obs(&obs);
    let r = BoundedPrune::default().run(&space, &ctx).unwrap();
    writer.finalize(&r).unwrap();
    obs.trace.as_ref().unwrap().finish().unwrap();
    let text = std::fs::read_to_string(&trace_path).unwrap();
    std::fs::remove_file(&trace_path).ok();
    std::fs::remove_file(&jnl_path).ok();

    // the whole file is one JSON array
    let whole = Json::parse(&text).unwrap();
    let events = whole.as_arr().unwrap();
    assert!(events.len() >= 2 + 4 * r.evaluated, "one span per phase at least");

    // every line (minus its separator comma) is a complete event, and
    // B/E events nest properly per track in file order
    let mut stacks: HashMap<u64, Vec<String>> = HashMap::new();
    for line in text.lines() {
        let bare = line.trim().trim_end_matches(',');
        if bare == "[" || bare == "]" || bare.is_empty() {
            continue;
        }
        let e = Json::parse(bare).unwrap();
        let ph = e.field("ph").unwrap().as_str().unwrap().to_string();
        let tid = e.field("tid").unwrap().as_u64().unwrap();
        let name = e.field("name").unwrap().as_str().unwrap().to_string();
        assert!(e.field("pid").unwrap().as_u64().unwrap() > 0);
        assert!(e.field("ts").unwrap().as_f64().unwrap() >= 0.0);
        match ph.as_str() {
            "B" => stacks.entry(tid).or_default().push(name),
            "E" => {
                let open = stacks.entry(tid).or_default().pop();
                assert_eq!(open.as_deref(), Some(name.as_str()), "unbalanced E");
            }
            "M" => assert_eq!(name, "thread_name"),
            other => panic!("unexpected event phase `{other}`"),
        }
    }
    for (tid, stack) in &stacks {
        assert!(stack.is_empty(), "track {tid} has unclosed spans: {stack:?}");
    }

    // the expected spans are all present
    for needle in ["compile", "resource-replay", "timing", "power", "wave m=1", "fsync"] {
        assert!(text.contains(needle), "trace is missing `{needle}` spans");
    }
}

/// Instrumentation must be observation only: the same sweep with and
/// without an observer returns bit-identical evaluations and counters.
#[test]
fn observed_sweep_results_match_unobserved() {
    let space = small_space();
    for strategy in strategies() {
        let bare_cache = EvalCache::new();
        let bare_ctx = SweepContext::new(&bare_cache, 2);
        let bare = strategy.run(&space, &bare_ctx).unwrap();

        let obs = Obs::new();
        let obs_cache = EvalCache::new();
        let obs_ctx = SweepContext::new(&obs_cache, 2).with_obs(&obs);
        let seen = strategy.run(&space, &obs_ctx).unwrap();

        assert_eq!(bare.evaluated, seen.evaluated, "{}", strategy.name());
        assert_eq!(bare.cache_hits, seen.cache_hits, "{}", strategy.name());
        assert_eq!(bare.skipped, seen.skipped, "{}", strategy.name());
        assert_eq!(bare.evals.len(), seen.evals.len(), "{}", strategy.name());
        for (a, b) in bare.evals.iter().zip(&seen.evals) {
            assert_eq!(a.design, b.design);
            assert_eq!(a.perf_per_watt.to_bits(), b.perf_per_watt.to_bits());
            assert_eq!(a.power_w.to_bits(), b.power_w.to_bits());
        }
    }
}

/// Minimal HTTP/1.1 GET returning the raw response (headers + body).
fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .write_all(format!("GET {path} HTTP/1.1\r\nHost: test\r\n\r\n").as_bytes())
        .unwrap();
    let mut out = String::new();
    stream.read_to_string(&mut out).unwrap();
    out
}

/// Concurrent scrapers against a live sweep: every `/metrics` response
/// must be grammatical Prometheus exposition, every `/status` must be
/// valid JSON, and the `sweep_rows` counter must never go backwards —
/// even while the worker pool is mutating the registry underneath.
#[test]
fn live_endpoint_serves_consistent_scrapes_mid_sweep() {
    let space = DesignSpace::from_explore(&ExploreConfig {
        grid_w: 64,
        grid_h: 32,
        max_n: 3,
        max_m: 3,
        passes: 2,
        ..Default::default()
    });
    let obs = Arc::new(Obs::new());
    let cache = Arc::new(EvalCache::new());
    let id = SweepIdentity {
        workload: space.workload.to_string(),
        strategy: "exhaustive".to_string(),
        fingerprint: space_fingerprint(&space),
        candidates: space.len(),
    };
    let (obs2, cache2) = (Arc::clone(&obs), Arc::clone(&cache));
    let status: StatusFn =
        Arc::new(move || status_json(&id, &obs2, &cache2, None));
    let mut server =
        ObsServer::start("127.0.0.1:0", Arc::clone(&obs), status).unwrap();
    let addr = server.addr();

    let result = std::thread::scope(|s| {
        let sweep = s.spawn(|| {
            let ctx = SweepContext::new(&cache, 2).with_obs(&obs);
            Exhaustive.run(&space, &ctx).unwrap()
        });
        let scrapers: Vec<_> = (0..4)
            .map(|_| {
                s.spawn(move || {
                    let mut last_rows = 0u64;
                    for _ in 0..10 {
                        let rsp = http_get(addr, "/metrics");
                        assert!(rsp.contains("version=0.0.4"), "{rsp}");
                        let body = rsp.split("\r\n\r\n").nth(1).unwrap();
                        for line in
                            body.lines().filter(|l| !l.starts_with('#') && !l.is_empty())
                        {
                            let (series, value) = line.rsplit_once(' ').expect(line);
                            assert!(!series.is_empty(), "{line}");
                            assert!(value.parse::<f64>().is_ok(), "{line}");
                            if series == "sweep_rows" {
                                let rows: u64 = value.parse().unwrap();
                                assert!(
                                    rows >= last_rows,
                                    "sweep_rows went backwards: {rows} < {last_rows}"
                                );
                                last_rows = rows;
                            }
                        }
                        let rsp = http_get(addr, "/status");
                        let body = rsp.split("\r\n\r\n").nth(1).unwrap();
                        let st = Json::parse(body.trim()).unwrap();
                        let progress = st.field("progress").unwrap();
                        let done = progress.field("done").unwrap().as_u64().unwrap();
                        let total = progress.field("total").unwrap().as_u64().unwrap();
                        assert!(done <= total, "{done} > {total}");
                        assert_eq!(
                            st.field("sweep")
                                .unwrap()
                                .field("strategy")
                                .unwrap()
                                .as_str()
                                .unwrap(),
                            "exhaustive"
                        );
                    }
                })
            })
            .collect();
        let r = sweep.join().unwrap();
        for h in scrapers {
            h.join().unwrap();
        }
        r
    });

    // after the sweep, one more scrape reconciles exactly
    let rsp = http_get(addr, "/metrics");
    let rows_line = rsp
        .lines()
        .find(|l| l.starts_with("sweep_rows "))
        .expect("sweep_rows series");
    assert_eq!(
        rows_line,
        format!("sweep_rows {}", result.evals.len()),
        "final scrape matches the result"
    );
    server.shutdown();
}

/// An injected slow evaluation must produce exactly one stall event:
/// the first watchdog scan past the threshold flags it, later scans
/// must not re-flag, and finishing the job resets the age gauge.
#[test]
fn watchdog_flags_a_stalled_evaluation_exactly_once() {
    let path = tmp("stall_events");
    let obs = Obs::new().with_events(EventLog::create(&path).unwrap());
    obs.job_started("eval lbm (n=4, m=4) 64x32 @ stratix-v");
    std::thread::sleep(Duration::from_millis(20));
    let stall_after = Some(1_000_000u64); // 1ms, long exceeded
    assert_eq!(scan_once(&obs, stall_after), 1, "first scan flags the stall");
    assert_eq!(scan_once(&obs, stall_after), 0, "second scan must not re-flag");
    assert_eq!(obs.metrics.counter("sweep.stalls").get(), 1);
    let w = &obs.worker_states()[0];
    assert!(w.busy && w.stalled);
    let gauge = obs.metrics.gauge(&format!("worker.{}.inflight_age_ns", w.name));
    assert!(gauge.get() >= 1_000_000, "{}", gauge.get());
    obs.job_finished();
    scan_once(&obs, stall_after);
    assert_eq!(gauge.get(), 0, "idle worker reads age 0");

    obs.events.as_ref().unwrap().flush().unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let records = parse_event_log(&text).unwrap();
    let stalls: Vec<&Json> = records
        .iter()
        .filter(|r| r.field("event").unwrap().as_str().unwrap() == "stall")
        .collect();
    assert_eq!(stalls.len(), 1, "exactly one stall event: {text}");
    assert_eq!(stalls[0].field("worker").unwrap().as_str().unwrap(), w.name);
    assert!(stalls[0]
        .field("job")
        .unwrap()
        .as_str()
        .unwrap()
        .contains("n=4, m=4"));
    assert!(stalls[0].field("age_ns").unwrap().as_u64().unwrap() >= 1_000_000);
}

/// The background watchdog thread detects the same injected stall on
/// its own tick, still exactly once across many scans.
#[test]
fn watchdog_thread_detects_an_injected_stall_once() {
    let obs = Arc::new(Obs::new());
    obs.job_started("eval sleepy");
    let mut dog =
        Watchdog::start(Arc::clone(&obs), Some(Duration::from_millis(5))).unwrap();
    // tick is clamped to 10ms, so ~8 scans happen in this window
    std::thread::sleep(Duration::from_millis(80));
    dog.shutdown();
    assert_eq!(obs.metrics.counter("sweep.stalls").get(), 1);
    obs.job_finished();
}

/// A full CLI sweep with `--events` writes a log that reconciles with
/// the sweep: gapless sequence from 1, exactly one paired
/// `sweep-start` / `sweep-finish`, waves in between, and finish totals
/// matching the space.
#[test]
fn cli_sweep_event_log_reconciles_with_the_sweep() {
    let events = tmp("cli_events");
    let code = spdx::cli::run(vec![
        "dse".into(),
        "sweep".into(),
        "--grids".into(),
        "64x32".into(),
        "--max-n".into(),
        "2".into(),
        "--max-m".into(),
        "2".into(),
        "--passes".into(),
        "2".into(),
        "--events".into(),
        events.to_string_lossy().into_owned(),
    ])
    .unwrap();
    assert_eq!(code, 0);
    let text = std::fs::read_to_string(&events).unwrap();
    std::fs::remove_file(&events).ok();
    let records = parse_event_log(&text).unwrap();
    for (i, r) in records.iter().enumerate() {
        assert_eq!(
            r.field("seq").unwrap().as_u64().unwrap(),
            i as u64 + 1,
            "gapless sequence"
        );
    }
    let names: Vec<&str> = records
        .iter()
        .map(|r| r.field("event").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(names.first(), Some(&"sweep-start"), "{names:?}");
    assert_eq!(names.last(), Some(&"sweep-finish"), "{names:?}");
    assert_eq!(names.iter().filter(|n| **n == "sweep-start").count(), 1);
    assert_eq!(names.iter().filter(|n| **n == "sweep-finish").count(), 1);
    assert!(names.contains(&"wave-start"), "{names:?}");
    let start = &records[0];
    assert_eq!(start.field("candidates").unwrap().as_u64().unwrap(), 4);
    let finish = records.last().unwrap();
    assert_eq!(finish.field("rows").unwrap().as_u64().unwrap(), 4);
    assert_eq!(finish.field("evaluated").unwrap().as_u64().unwrap(), 4);
    assert_eq!(finish.field("skipped").unwrap().as_u64().unwrap(), 0);
}

/// A sweep that errors mid-setup must still flush its telemetry: the
/// metrics file exists and is marked partial, the trace is valid JSON,
/// and the event log records the `sweep-error`.
#[test]
fn error_path_flushes_partial_telemetry() {
    let missing_dir = tmp("errflush_nonexistent_dir");
    let jnl = missing_dir.join("x.jnl"); // parent does not exist
    let metrics = tmp("errflush_metrics");
    let trace = tmp("errflush_trace");
    let events = tmp("errflush_events");
    let err = spdx::cli::run(vec![
        "dse".into(),
        "sweep".into(),
        "--grids".into(),
        "64x32".into(),
        "--max-n".into(),
        "2".into(),
        "--max-m".into(),
        "2".into(),
        "--passes".into(),
        "2".into(),
        "--journal".into(),
        jnl.to_string_lossy().into_owned(),
        "--metrics".into(),
        metrics.to_string_lossy().into_owned(),
        "--trace".into(),
        trace.to_string_lossy().into_owned(),
        "--events".into(),
        events.to_string_lossy().into_owned(),
    ])
    .unwrap_err();
    assert!(!err.to_string().is_empty());

    let m = Json::parse(&std::fs::read_to_string(&metrics).unwrap()).unwrap();
    assert_eq!(
        m.field("gauges")
            .unwrap()
            .field("sweep.partial")
            .unwrap()
            .as_u64()
            .unwrap(),
        1,
        "partial snapshot is marked"
    );
    let trace_text = std::fs::read_to_string(&trace).unwrap();
    Json::parse(&trace_text).unwrap().as_arr().unwrap();
    let ev =
        parse_event_log(&std::fs::read_to_string(&events).unwrap()).unwrap();
    assert!(
        ev.iter()
            .any(|r| r.field("event").unwrap().as_str().unwrap() == "sweep-error"),
        "event log records the failure"
    );
    std::fs::remove_file(&metrics).ok();
    std::fs::remove_file(&trace).ok();
    std::fs::remove_file(&events).ok();
}

/// `--listen` + `--metrics-every` end to end through the CLI: the run
/// exits cleanly (server and snapshot writer shut down) and the final
/// snapshot records at least two writes (the writer's immediate first
/// write plus the shutdown write).
#[test]
fn cli_sweep_with_live_plane_writes_periodic_snapshots() {
    let metrics = tmp("live_metrics");
    let code = spdx::cli::run(vec![
        "dse".into(),
        "sweep".into(),
        "--grids".into(),
        "64x32".into(),
        "--max-n".into(),
        "2".into(),
        "--max-m".into(),
        "2".into(),
        "--passes".into(),
        "2".into(),
        "--listen".into(),
        "127.0.0.1:0".into(),
        "--stall-after".into(),
        "60".into(),
        "--metrics".into(),
        metrics.to_string_lossy().into_owned(),
        "--metrics-every".into(),
        "0.05".into(),
    ])
    .unwrap();
    assert_eq!(code, 0);
    let m = Json::parse(&std::fs::read_to_string(&metrics).unwrap()).unwrap();
    std::fs::remove_file(&metrics).ok();
    let snaps = m
        .field("counters")
        .unwrap()
        .field("obs.snapshots")
        .unwrap()
        .as_u64()
        .unwrap();
    assert!(snaps >= 2, "expected >= 2 snapshots, got {snaps}");
    assert_eq!(
        m.field("counters").unwrap().field("sweep.rows").unwrap().as_u64().unwrap(),
        4
    );
}
