//! Integration tests for the sweep telemetry subsystem: the metrics
//! registry must reconcile exactly with the sweep's own counters (no
//! double counting, no dropped rows), the trace file must be
//! well-formed Chrome `trace_event` JSON, and instrumentation must
//! never change sweep results.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use spdx::dse::json::Json;
use spdx::dse::{
    BoundedPrune, DesignSpace, EvalCache, Exhaustive, HillClimb, JournalWriter,
    SearchStrategy, SweepContext,
};
use spdx::explore::ExploreConfig;
use spdx::obs::{Obs, TraceSink};

fn small_space() -> DesignSpace {
    DesignSpace::from_explore(&ExploreConfig {
        grid_w: 64,
        grid_h: 32,
        max_n: 2,
        max_m: 2,
        passes: 2,
        ..Default::default()
    })
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("spdx_obs_{tag}_{}.tmp", std::process::id()))
}

fn strategies() -> Vec<Box<dyn SearchStrategy>> {
    vec![
        Box::new(Exhaustive),
        Box::new(BoundedPrune::default()),
        Box::new(HillClimb { seed: 7, restarts: 2, max_steps: 8 }),
    ]
}

/// The registry's totals must equal the `SweepResult` counters and the
/// journal's row count exactly, for every strategy — the telemetry is
/// a view of the sweep, not an estimate of it.
#[test]
fn metrics_reconcile_with_sweep_result_for_all_strategies() {
    let space = small_space();
    for strategy in strategies() {
        let name = strategy.name();
        let path = tmp(&format!("reconcile_{name}"));
        let obs = Arc::new(Obs::new());
        let cache = EvalCache::new();
        let writer = JournalWriter::create(&path, name, &space)
            .unwrap()
            .with_sync_every(1)
            .with_obs(obs.clone());
        let ctx = SweepContext::new(&cache, 2).with_sink(&writer).with_obs(&obs);
        let r = strategy.run(&space, &ctx).unwrap();
        writer.finalize(&r).unwrap();

        let count = |metric: &str| obs.metrics.counter(metric).get();
        assert_eq!(count("sweep.evaluated"), r.evaluated as u64, "{name}");
        assert_eq!(count("sweep.cache_hits"), r.cache_hits, "{name}");
        assert_eq!(count("sweep.skipped"), r.skipped as u64, "{name}");
        assert_eq!(
            count("sweep.rows"),
            r.evaluated as u64 + r.cache_hits,
            "{name}: every completed row is counted exactly once"
        );
        assert_eq!(count("sweep.errors"), 0, "{name}");

        // the journal deduplicates, so its rows are the distinct
        // evaluations — exactly the result's eval list
        assert_eq!(writer.rows_written(), r.evals.len() as u64, "{name}");
        assert!(writer.fsyncs() >= 1 + r.evals.len() as u64, "{name}");

        // cache: every real evaluation was a miss, and the per-shard
        // counters sum to the totals
        let total = cache.stats();
        assert_eq!(total.misses, r.evaluated as u64, "{name}");
        let shards = cache.shard_stats();
        assert_eq!(shards.iter().map(|s| s.hits).sum::<u64>(), total.hits);
        assert_eq!(shards.iter().map(|s| s.misses).sum::<u64>(), total.misses);
        assert_eq!(shards.iter().map(|s| s.entries).sum::<usize>(), total.entries);

        // latency histograms: one sample per real evaluation in the
        // total and in each phase (cache hits must not pollute them)
        assert_eq!(obs.eval_stats().count, r.evaluated as u64, "{name}");
        for (phase, st) in obs.phase_stats() {
            assert_eq!(st.count, r.evaluated as u64, "{name}/{phase}");
            assert!(st.p50 <= st.p95 && st.p95 <= st.max, "{name}/{phase}");
        }

        // per-strategy coverage identity over the whole space
        match name {
            "exhaustive" | "bounded-prune" => {
                assert_eq!(r.evaluated + r.skipped, r.candidates, "{name}");
                assert_eq!(r.cache_hits, 0, "{name}: fresh cache");
            }
            _ => assert_eq!(r.evals.len() + r.skipped, r.candidates, "{name}"),
        }
        std::fs::remove_file(&path).ok();
    }
}

/// The trace must parse as one JSON array, every event line must be a
/// complete object with pid/tid/ts, and every `B` must have a matching
/// `E` on the same track, in order.
#[test]
fn trace_file_is_well_formed_chrome_json() {
    let space = small_space();
    let trace_path = tmp("trace");
    let jnl_path = tmp("trace_jnl");
    let obs =
        Arc::new(Obs::new().with_trace(TraceSink::create(&trace_path).unwrap()));
    let cache = EvalCache::new();
    let writer = JournalWriter::create(&jnl_path, "bounded-prune", &space)
        .unwrap()
        .with_sync_every(1)
        .with_obs(obs.clone());
    let ctx = SweepContext::new(&cache, 2).with_sink(&writer).with_obs(&obs);
    let r = BoundedPrune::default().run(&space, &ctx).unwrap();
    writer.finalize(&r).unwrap();
    obs.trace.as_ref().unwrap().finish().unwrap();
    let text = std::fs::read_to_string(&trace_path).unwrap();
    std::fs::remove_file(&trace_path).ok();
    std::fs::remove_file(&jnl_path).ok();

    // the whole file is one JSON array
    let whole = Json::parse(&text).unwrap();
    let events = whole.as_arr().unwrap();
    assert!(events.len() >= 2 + 4 * r.evaluated, "one span per phase at least");

    // every line (minus its separator comma) is a complete event, and
    // B/E events nest properly per track in file order
    let mut stacks: HashMap<u64, Vec<String>> = HashMap::new();
    for line in text.lines() {
        let bare = line.trim().trim_end_matches(',');
        if bare == "[" || bare == "]" || bare.is_empty() {
            continue;
        }
        let e = Json::parse(bare).unwrap();
        let ph = e.field("ph").unwrap().as_str().unwrap().to_string();
        let tid = e.field("tid").unwrap().as_u64().unwrap();
        let name = e.field("name").unwrap().as_str().unwrap().to_string();
        assert!(e.field("pid").unwrap().as_u64().unwrap() > 0);
        assert!(e.field("ts").unwrap().as_f64().unwrap() >= 0.0);
        match ph.as_str() {
            "B" => stacks.entry(tid).or_default().push(name),
            "E" => {
                let open = stacks.entry(tid).or_default().pop();
                assert_eq!(open.as_deref(), Some(name.as_str()), "unbalanced E");
            }
            "M" => assert_eq!(name, "thread_name"),
            other => panic!("unexpected event phase `{other}`"),
        }
    }
    for (tid, stack) in &stacks {
        assert!(stack.is_empty(), "track {tid} has unclosed spans: {stack:?}");
    }

    // the expected spans are all present
    for needle in ["compile", "resource-replay", "timing", "power", "wave m=1", "fsync"] {
        assert!(text.contains(needle), "trace is missing `{needle}` spans");
    }
}

/// Instrumentation must be observation only: the same sweep with and
/// without an observer returns bit-identical evaluations and counters.
#[test]
fn observed_sweep_results_match_unobserved() {
    let space = small_space();
    for strategy in strategies() {
        let bare_cache = EvalCache::new();
        let bare_ctx = SweepContext::new(&bare_cache, 2);
        let bare = strategy.run(&space, &bare_ctx).unwrap();

        let obs = Obs::new();
        let obs_cache = EvalCache::new();
        let obs_ctx = SweepContext::new(&obs_cache, 2).with_obs(&obs);
        let seen = strategy.run(&space, &obs_ctx).unwrap();

        assert_eq!(bare.evaluated, seen.evaluated, "{}", strategy.name());
        assert_eq!(bare.cache_hits, seen.cache_hits, "{}", strategy.name());
        assert_eq!(bare.skipped, seen.skipped, "{}", strategy.name());
        assert_eq!(bare.evals.len(), seen.evals.len(), "{}", strategy.name());
        for (a, b) in bare.evals.iter().zip(&seen.evals) {
            assert_eq!(a.design, b.design);
            assert_eq!(a.perf_per_watt.to_bits(), b.perf_per_watt.to_bits());
            assert_eq!(a.power_w.to_bits(), b.power_w.to_bits());
        }
    }
}
