//! The chaos suite: deterministic fault injection against the sweep
//! supervisor.
//!
//! Every test drives a real sweep (real strategies, real worker pool,
//! real journal) through a [`FaultPlan`] and checks the supervision
//! contract from the outside:
//!
//! * a transient fault (panic, I/O error, delay) costs *retries*, not
//!   rows — the sweep converges to the unfaulted result;
//! * a persistent fault costs exactly one row (quarantine), never the
//!   run;
//! * a deadline cancels a hung evaluation cooperatively and the point
//!   is requeued once before quarantine;
//! * a sink fault degrades the journal to memory-only instead of
//!   aborting;
//! * replaying a faulted sweep with the same seed reproduces the same
//!   failures and bit-identical surviving rows.

use std::sync::Arc;
use std::time::Duration;

use spdx::coordinator::supervise::backoff_delay;
use spdx::coordinator::{DegradingSink, Fault, FaultKind, FaultPlan, Supervisor};
use spdx::dse::{
    DesignSpace, EvalCache, Exhaustive, FailKind, FailRow, Journal,
    JournalWriter, SearchStrategy, SweepContext, SweepResult,
};
use spdx::obs::Obs;
use spdx::resource::STRATIX_V_5SGXEA7;

fn small_space(workload: &'static str) -> DesignSpace {
    DesignSpace {
        workload,
        grids: vec![(32, 16)],
        max_n: 2,
        max_m: 2,
        devices: vec![&STRATIX_V_5SGXEA7],
        ddr_variants: vec![Default::default()],
        passes: 2,
        latency: Default::default(),
    }
}

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("spdx_faults_{tag}_{}.jnl", std::process::id()))
}

/// Keyed, comparable view of a result's rows (completion order is
/// scheduling-dependent under a worker pool).
fn row_bits(r: &SweepResult) -> Vec<(u32, u32, u64)> {
    let mut v: Vec<(u32, u32, u64)> = r
        .evals
        .iter()
        .map(|e| (e.design.n, e.design.m, e.perf_per_watt.to_bits()))
        .collect();
    v.sort();
    v
}

fn fail_keys(failures: &[FailRow]) -> Vec<(u32, u32, &'static str, u32)> {
    let mut v: Vec<(u32, u32, &'static str, u32)> = failures
        .iter()
        .map(|f| (f.design.n, f.design.m, f.kind.label(), f.attempts))
        .collect();
    v.sort();
    v
}

/// Transient faults — a panic, a double I/O error, a short delay — are
/// absorbed by the retry budget: zero quarantines, and the rows are
/// bit-identical to a sweep that never faulted.
#[test]
fn transient_faults_are_retried_to_convergence() {
    let space = small_space("jacobi");
    let cache = EvalCache::new();
    let clean = Exhaustive.run(&space, &SweepContext::new(&cache, 2)).unwrap();
    assert_eq!(clean.evals.len(), 4);

    let plan = Arc::new(
        FaultPlan::new()
            .with_fault(Fault::new(FaultKind::Panic).at_n(1).at_m(1).times(1))
            .with_fault(Fault::new(FaultKind::IoError).at_n(2).at_m(1).times(2))
            .with_fault(Fault::new(FaultKind::Delay(20)).at_n(1).at_m(2).times(1)),
    );
    let sup = Supervisor::new()
        .with_retries(2)
        .with_backoff(Duration::ZERO)
        .with_seed(42)
        .with_faults(plan);
    let obs = Obs::new();
    let cache = EvalCache::new();
    let ctx = SweepContext::new(&cache, 2).with_obs(&obs).with_supervisor(&sup);
    let faulted = Exhaustive.run(&space, &ctx).unwrap();

    assert!(faulted.failures.is_empty(), "retries must absorb the faults");
    assert_eq!(row_bits(&faulted), row_bits(&clean), "rows are bit-identical");
    // one panic retry + two io-error retries (the delay only sleeps)
    assert_eq!(obs.metrics.counter("sweep.retries").get(), 3);
    assert_eq!(obs.metrics.counter("sweep.failed").get(), 0);
}

/// A point that panics on every attempt is quarantined after the
/// budget — one lost row, the rest of the sweep untouched — and the
/// journal records the fail row alongside the surviving rows.
#[test]
fn persistent_panic_costs_one_row_not_the_run() {
    let space = small_space("lbm");
    let path = tmp("poison");
    let plan =
        Arc::new(FaultPlan::new().with_fault(Fault::new(FaultKind::Panic).at_n(2).at_m(2)));
    let sup = Supervisor::new()
        .with_retries(2)
        .with_backoff(Duration::ZERO)
        .with_faults(plan);
    let cache = EvalCache::new();
    let writer =
        JournalWriter::create(&path, "exhaustive", &space).unwrap().with_sync_every(1);
    let ctx = SweepContext::new(&cache, 2).with_sink(&writer).with_supervisor(&sup);
    let result = Exhaustive.run(&space, &ctx).unwrap();
    writer.finalize(&result).unwrap();

    assert_eq!(result.evals.len(), 3);
    assert_eq!(result.failures.len(), 1);
    let f = &result.failures[0];
    assert_eq!((f.design.n, f.design.m), (2, 2));
    assert_eq!(f.kind, FailKind::Panic);
    assert_eq!(f.attempts, 3, "initial attempt + two retries");
    assert!(f.error.contains("injected panic"), "{}", f.error);

    let j = Journal::recover(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(j.complete());
    assert_eq!(j.rows.len(), 3);
    assert_eq!(j.failures.len(), 1);
    assert_eq!((j.failures[0].design.n, j.failures[0].design.m), (2, 2));
}

/// A hung evaluation (10s injected delay) is cancelled at the deadline
/// inside the cooperative checkpoint, requeued exactly once, then
/// quarantined as a timeout.
#[test]
fn deadline_cancels_a_hung_evaluation_and_requeues_once() {
    let space = small_space("wave");
    let plan = Arc::new(
        FaultPlan::new().with_fault(Fault::new(FaultKind::Delay(10_000)).at_n(2).at_m(2)),
    );
    let sup = Supervisor::new()
        .with_retries(2)
        .with_backoff(Duration::ZERO)
        // generous deadline: honest evaluations of this space finish in
        // milliseconds even in debug builds, only the injected 10s
        // delay can trip it
        .with_eval_timeout(Duration::from_secs(1))
        .with_faults(plan);
    let cache = EvalCache::new();
    let ctx = SweepContext::new(&cache, 2).with_supervisor(&sup);
    let t0 = std::time::Instant::now();
    let result = Exhaustive.run(&space, &ctx).unwrap();
    let dt = t0.elapsed();

    assert_eq!(result.evals.len(), 3);
    assert_eq!(result.failures.len(), 1);
    let f = &result.failures[0];
    assert_eq!(f.kind, FailKind::Timeout);
    assert_eq!(f.attempts, 2, "a deadline miss is requeued exactly once");
    assert!(f.error.contains("deadline"), "{}", f.error);
    // two ~100ms deadlines, not two 10s sleeps
    assert!(dt < Duration::from_secs(8), "deadline must cut the delay short: {dt:?}");
}

/// Without `keep_going` the supervisor is fail-fast: the exhausted
/// point aborts the sweep with its job context, like the unsupervised
/// path.
#[test]
fn fail_fast_aborts_with_the_faulted_point_in_the_error() {
    let space = small_space("lbm");
    let plan =
        Arc::new(FaultPlan::new().with_fault(Fault::new(FaultKind::Panic).at_n(1).at_m(1)));
    let sup = Supervisor::new()
        .with_retries(0)
        .with_backoff(Duration::ZERO)
        .with_keep_going(false)
        .with_faults(plan);
    let cache = EvalCache::new();
    let ctx = SweepContext::new(&cache, 2).with_supervisor(&sup);
    let err = Exhaustive.run(&space, &ctx).unwrap_err().to_string();
    assert!(err.contains("injected panic"), "{err}");
    assert!(err.contains("n=1"), "job context names the point: {err}");
}

/// A sink fault mid-sweep degrades the journal to memory-only: the
/// sweep still produces every row, the journal keeps only the prefix
/// written before the fault, and the degradation is observable.
#[test]
fn sink_fault_degrades_the_journal_not_the_sweep() {
    let space = small_space("blur");
    let path = tmp("degrade");
    let plan =
        Arc::new(FaultPlan::new().with_fault(Fault::new(FaultKind::SinkError).times(1)));
    let sup = Supervisor::new().with_backoff(Duration::ZERO).with_faults(plan);
    let obs = Obs::new();
    let cache = EvalCache::new();
    let writer =
        JournalWriter::create(&path, "exhaustive", &space).unwrap().with_sync_every(1);
    let sink = DegradingSink::new(&writer)
        .with_obs(&obs)
        .with_faults(sup.faults().unwrap());
    let ctx = SweepContext::new(&cache, 2)
        .with_sink(&sink)
        .with_obs(&obs)
        .with_supervisor(&sup);
    let result = Exhaustive.run(&space, &ctx).unwrap();

    assert_eq!(result.evals.len(), 4, "the sweep kept all its rows");
    assert!(result.failures.is_empty());
    assert!(sink.is_degraded());
    assert_eq!(obs.metrics.gauge("sweep.sink_degraded").get(), 1);
    // the degraded journal is left unfinalized (the CLI skips the
    // finalize record for exactly this case) so a resume can fill it
    let j = Journal::recover(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(!j.complete());
    assert!(j.rows.len() < result.evals.len(), "rows after the fault are missing");
}

/// Pre-quarantined content addresses fail instantly — no evaluation,
/// no retries — with an error that points at `--retry-failed`.
#[test]
fn seeded_quarantine_skips_the_point_without_evaluating() {
    let space = small_space("jacobi");
    // learn the poisoned point's content address from a faulted run
    let plan =
        Arc::new(FaultPlan::new().with_fault(Fault::new(FaultKind::Panic).at_n(2).at_m(1)));
    let sup = Supervisor::new()
        .with_retries(0)
        .with_backoff(Duration::ZERO)
        .with_faults(plan);
    let cache = EvalCache::new();
    let first = Exhaustive
        .run(&space, &SweepContext::new(&cache, 2).with_supervisor(&sup))
        .unwrap();
    assert_eq!(first.failures.len(), 1);
    let key = first.failures[0].key(space.latency);

    let sup = Supervisor::new().with_quarantine([key]);
    assert_eq!(sup.quarantined(), 1);
    let cache = EvalCache::new();
    let result = Exhaustive
        .run(&space, &SweepContext::new(&cache, 2).with_supervisor(&sup))
        .unwrap();
    assert_eq!(result.evals.len(), 3);
    assert_eq!(result.failures.len(), 1);
    let f = &result.failures[0];
    assert_eq!((f.design.n, f.design.m), (2, 1));
    assert_eq!(f.attempts, 0, "a quarantined point is never attempted");
    assert!(f.error.contains("--retry-failed"), "{}", f.error);
    assert_eq!(cache.stats().misses, 3, "only the live points evaluated");
}

/// The replay guarantee: the same fault plan under the same seed
/// produces the same failures (points, kinds, attempt counts) and
/// bit-identical surviving rows, run after run.
#[test]
fn faulted_sweeps_replay_bit_identically() {
    let run_once = || {
        let space = small_space("lbm");
        let plan = Arc::new(
            FaultPlan::new()
                .with_fault(Fault::new(FaultKind::Panic).at_n(2).at_m(2))
                .with_fault(Fault::new(FaultKind::IoError).at_n(1).at_m(1).times(1)),
        );
        let sup = Supervisor::new()
            .with_retries(1)
            .with_backoff(Duration::from_millis(1))
            .with_seed(7)
            .with_faults(plan);
        let cache = EvalCache::new();
        let r = Exhaustive
            .run(&space, &SweepContext::new(&cache, 2).with_supervisor(&sup))
            .unwrap();
        (row_bits(&r), fail_keys(&r.failures).into_iter().map(
            |(n, m, k, a)| (n, m, k.to_string(), a)).collect::<Vec<_>>())
    };
    let a = run_once();
    let b = run_once();
    assert_eq!(a, b, "replays must agree exactly");
    assert_eq!(a.1, vec![(2, 2, "panic".to_string(), 2)]);
}

/// The backoff schedule is a pure function of (base, seed, job hash,
/// retry ordinal): exponential growth with jitter in [0.5, 1.0), and
/// deterministic across calls.
#[test]
fn backoff_schedule_is_deterministic_and_bounded() {
    let base = Duration::from_millis(32);
    for retry in 1..=4u32 {
        let d = backoff_delay(base, 11, 0xfeed, retry);
        assert_eq!(d, backoff_delay(base, 11, 0xfeed, retry), "replay");
        let exp = base * (1u32 << (retry - 1));
        assert!(d >= exp / 2 && d < exp, "retry {retry}: {d:?} vs {exp:?}");
    }
    assert_eq!(backoff_delay(Duration::ZERO, 11, 0xfeed, 1), Duration::ZERO);
    // different seeds and jobs draw different jitter (overwhelmingly)
    assert_ne!(
        backoff_delay(base, 11, 0xfeed, 1),
        backoff_delay(base, 12, 0xbeef, 1)
    );
}
