//! End-to-end driver (DESIGN.md experiment E2E): runs the full system
//! on a real small workload, proving all layers compose.
//!
//! A 64x64 lid-driven-cavity flow is advanced 200 time steps through
//! four independent implementations:
//!
//!   1. the compiled SPD hardware (dataflow semantics of the balanced
//!      pipeline) — the paper's FPGA core, on the simulated substrate;
//!   2. the same hardware through the cycle-accurate engine (every
//!      pipeline register exercised) for the first 10 steps;
//!   3. the Rust software reference;
//!   4. the JAX/Pallas kernel, AOT-lowered to HLO and executed from
//!      Rust via PJRT (`artifacts/lbm_cascade10_64x64.hlo.txt`) —
//!      python never runs here.
//!
//! It reports cross-implementation agreement (the paper's §III-A
//! verification), the physics of the developed flow, and the measured
//! throughput of each path.
//!
//! Run: `make artifacts && cargo run --release --example lbm_simulation`

use spdx::lbm::reference::{self, LbmState};
use spdx::lbm::workload::{fluid_max_diff, LbmRunner};
use spdx::lbm::{LbmCoreNames, LbmDesign, FLUID};
use spdx::runtime::{dense_to_state, state_to_dense, PjrtRuntime};

const H: usize = 64;
const W: usize = 64;
const STEPS: u32 = 200;
const TAU: f32 = 0.6;

fn main() -> spdx::Result<()> {
    let one_tau = 1.0 / TAU;
    let init = LbmState::cavity(H, W);

    // ---- 1. compiled SPD hardware (dataflow semantics) --------------
    let runner = LbmRunner::new(LbmDesign::new(1, 1, W as u32, H as u32))?;
    println!(
        "SPD design {} compiled: PE depth {} stages, {} FP ops",
        runner.design.top_name(),
        runner.generated.pe_depth,
        runner.compiled.graph.census().total()
    );
    let t0 = std::time::Instant::now();
    let hw = runner.run_dataflow(init.clone(), one_tau, STEPS)?;
    let dt_hw = t0.elapsed().as_secs_f64();

    // ---- 2. cycle-accurate engine (10 steps) -------------------------
    let t0 = std::time::Instant::now();
    let (cy, cycles) = runner.run_cycle_accurate(init.clone(), one_tau, 10)?;
    let dt_cy = t0.elapsed().as_secs_f64();
    let hw10 = runner.run_dataflow(init.clone(), one_tau, 10)?;
    let d_cy = fluid_max_diff(&cy, &hw10);
    println!(
        "cycle-accurate engine: {cycles} cycles for 10 steps in {dt_cy:.2}s \
         ({:.1} Mcycle/s), diff vs dataflow {d_cy:.2e}",
        cycles as f64 / dt_cy / 1e6
    );
    assert!(d_cy < 1e-6, "cycle-accurate must equal dataflow");

    // ---- 3. Rust software reference ----------------------------------
    let t0 = std::time::Instant::now();
    let sw = reference::run(init.clone(), one_tau, STEPS as usize);
    let dt_sw = t0.elapsed().as_secs_f64();

    // ---- 4. PJRT oracle (Pallas kernel, scan-fused 10-step cascade) --
    // degrades gracefully when the backend is unavailable (stub build
    // without the `pjrt` feature, or artifacts not built)
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut rt = PjrtRuntime::new(&artifacts)?;
    let oracle_run = (|| -> spdx::Result<(LbmState, f64)> {
        let (mut fdense, attr) = state_to_dense(&init);
        let t0 = std::time::Instant::now();
        for _ in 0..STEPS / 10 {
            fdense = rt.run_lbm("lbm_cascade10_64x64", &fdense, &attr, one_tau, H, W)?;
        }
        Ok((dense_to_state(&fdense, &init), t0.elapsed().as_secs_f64()))
    })();

    // ---- cross-validation -------------------------------------------
    let d_hw_sw = fluid_max_diff(&hw, &sw);
    println!("\n== verification ({STEPS} steps, fluid cells) ==");
    println!("SPD hardware vs rust reference : {d_hw_sw:.3e}");
    let dt_or = match &oracle_run {
        Ok((oracle, dt_or)) => {
            let d_hw_or = fluid_max_diff(&hw, oracle);
            println!("SPD hardware vs PJRT/Pallas    : {d_hw_or:.3e}");
            assert!(d_hw_or < 5e-4, "hardware vs oracle diverged: {d_hw_or}");
            Some(*dt_or)
        }
        Err(e) => {
            println!("SPD hardware vs PJRT/Pallas    : skipped ({e})");
            None
        }
    };
    assert!(d_hw_sw < 5e-4, "hardware vs reference diverged: {d_hw_sw}");

    // ---- physics ------------------------------------------------------
    println!("\n== physics of the developed cavity flow ==");
    let mut ux_top = 0.0f32;
    let mut ux_mid = 0.0f32;
    for x in 8..W - 8 {
        ux_top += hw.macros(W + x).1;
        ux_mid += hw.macros((H / 2) * W + x).1;
    }
    ux_top /= (W - 16) as f32;
    ux_mid /= (W - 16) as f32;
    println!("mean ux just below lid : {ux_top:+.4} (lid +0.1)");
    println!("mean ux at mid-depth   : {ux_mid:+.4} (return flow)");
    assert!(ux_top > 0.01 && ux_mid < 0.0, "no cavity vortex developed");
    let mass0 = init.fluid_mass();
    let mass1 = hw.fluid_mass();
    println!(
        "fluid mass             : {mass1:.3} vs initial {mass0:.3} ({:+.2e} rel)",
        (mass1 - mass0) / mass0
    );

    // ---- throughput ---------------------------------------------------
    let cells = (H * W) as f64 * STEPS as f64;
    println!("\n== throughput (64x64, {STEPS} steps) ==");
    println!(
        "SPD dataflow sim  : {:.2}s  ({:.2} Mcell-step/s)",
        dt_hw,
        cells / dt_hw / 1e6
    );
    println!(
        "rust reference    : {:.2}s  ({:.2} Mcell-step/s)",
        dt_sw,
        cells / dt_sw / 1e6
    );
    if let Some(dt_or) = dt_or {
        println!(
            "PJRT (Pallas AOT) : {:.2}s  ({:.2} Mcell-step/s, platform {})",
            dt_or,
            cells / dt_or / 1e6,
            rt.platform()
        );
    } else {
        println!("PJRT (Pallas AOT) : skipped ({})", rt.platform());
    }

    // count fluid cells for the record
    let n_fluid = init.attr.iter().filter(|&&a| a == FLUID).count();
    println!("\nE2E OK ({n_fluid} fluid cells verified)");
    Ok(())
}
