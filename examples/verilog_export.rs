//! Verilog export: compile the LBM PE (Fig. 6/7) and emit the
//! synthesizable netlist the paper's SPD compiler produces, plus DOT
//! graphs of the compiled DFGs (Figs. 7, 9, 12).
//!
//! Writes to `target/verilog_export/`:
//!   PEx1_w720.v, LBM_x1_m2_w720.v, shim_library.v,
//!   pe_x1.dot, cascade_m2.dot
//!
//! Run: `cargo run --release --example verilog_export`

use std::fs;
use std::path::PathBuf;

use spdx::dfg;
use spdx::lbm::spd_gen::{generate, LbmCoreNames, LbmDesign};
use spdx::spd::ModuleDef;
use spdx::verilog;

fn main() -> spdx::Result<()> {
    let out_dir = PathBuf::from("target/verilog_export");
    fs::create_dir_all(&out_dir)?;

    let design = LbmDesign::new(1, 2, 720, 300);
    let g = generate(&design)?;

    // the PE netlist (hierarchical: calc/bndry as module instances)
    let pe = match g.registry.lookup(&design.pe_name()) {
        Some(ModuleDef::Spd(c)) => c.clone(),
        _ => unreachable!(),
    };
    let pe_c = dfg::compile(&pe, &g.registry)?;
    let pe_v = verilog::emit(&pe_c.hier_graph, &pe_c.hier_schedule)?;
    fs::write(out_dir.join(format!("{}.v", design.pe_name())), &pe_v)?;

    // the two-PE cascade top (Figs. 10–12)
    let top_c = dfg::compile(&g.top, &g.registry)?;
    let top_v = verilog::emit(&top_c.hier_graph, &top_c.hier_schedule)?;
    fs::write(out_dir.join(format!("{}.v", design.top_name())), &top_v)?;

    // the IP shim library the netlists instantiate
    fs::write(out_dir.join("shim_library.v"), verilog::shim_library())?;

    // DOT graphs of the compiled DFGs (paper Figs. 7 / 12)
    fs::write(
        out_dir.join("pe_x1.dot"),
        dfg::to_dot(&pe_c.hier_graph, Some(&pe_c.hier_schedule)),
    )?;
    fs::write(
        out_dir.join("cascade_m2.dot"),
        dfg::to_dot(&top_c.hier_graph, Some(&top_c.hier_schedule)),
    )?;

    // also write the generated SPD sources themselves (Figs. 6/8/10/11)
    fs::write(out_dir.join("uLBM_calc.spd"), &g.calc_src)?;
    fs::write(out_dir.join("uLBM_bndry.spd"), &g.bndry_src)?;
    fs::write(out_dir.join(format!("{}.spd", design.pe_name())), &g.pe_src)?;
    fs::write(out_dir.join(format!("{}.spd", design.top_name())), &g.top_src)?;

    println!("wrote to {}:", out_dir.display());
    for entry in fs::read_dir(&out_dir)? {
        let e = entry?;
        println!("  {:<22} {:>9} bytes", e.file_name().to_string_lossy(), e.metadata()?.len());
    }
    // a flat emission of the PE shows the full operator-level netlist
    let pe_flat = verilog::emit(&pe_c.graph, &pe_c.schedule)?;
    fs::write(out_dir.join(format!("{}_flat.v", design.pe_name())), &pe_flat)?;
    println!(
        "\nPE depth {} stages; cascade depth {} stages; \
         {} module instances in the hierarchical PE netlist, \
         {} fp operator instances in the flat one",
        g.pe_depth,
        top_c.depth(),
        pe_v.matches("uLBM_").count(),
        pe_flat.matches("\n  fp_").count()
    );
    Ok(())
}
