//! Design-space exploration (the paper's §III): evaluate the six
//! (n, m) configurations — and every other feasible mix up to nm = 8 —
//! on the 720x300 grid, and reproduce the paper's conclusion that the
//! purely temporal (1, 4) design wins on performance per watt.
//!
//! Run: `cargo run --release --example design_space_exploration`

use spdx::coordinator::Coordinator;
use spdx::explore::{pareto, ExploreConfig};
use spdx::report;

fn main() -> spdx::Result<()> {
    let cfg = ExploreConfig {
        grid_w: 720,
        grid_h: 300,
        max_n: 8,
        max_m: 8,
        passes: 2,
        keep_infeasible: true,
        ..Default::default()
    };

    println!("exploring (n, m) up to n={}, m={} on {}x{} ...\n", cfg.max_n, cfg.max_m, cfg.grid_w, cfg.grid_h);
    let coord = Coordinator::new(cfg);
    let (evals, metrics) = coord.run()?;

    println!("{}", report::table3(&evals));

    let feasible: Vec<_> = evals.iter().filter(|e| e.infeasible.is_none()).collect();
    let best = feasible.first().expect("some feasible design");
    println!(
        "best perf/W overall: (n, m) = ({}, {}) at {:.3} GFlop/sW, {:.1} GFlop/s sustained",
        best.design.n, best.design.m, best.perf_per_watt, best.timing.performance_gflops
    );

    // within the paper's evaluated set {nm <= 4}, the winner must be the
    // pure temporal-parallel (1, 4) design (paper §III-C / §IV)
    let paper_best = feasible
        .iter()
        .filter(|e| e.design.n * e.design.m <= 4)
        .max_by(|a, b| a.perf_per_watt.partial_cmp(&b.perf_per_watt).unwrap())
        .unwrap();
    assert_eq!(
        (paper_best.design.n, paper_best.design.m),
        (1, 4),
        "the paper's winner is the pure temporal-parallel design"
    );
    println!(
        "paper-space winner : (1, 4) at {:.3} GFlop/sW (paper: 2.416)",
        paper_best.perf_per_watt
    );
    if (best.design.n, best.design.m) != (1, 4) {
        println!(
            "NOTE: beyond the paper's nm <= 4 sweep the explorer finds ({}, {}) \
             still fits the device ({} DSPs of 256) and improves perf/W — see \
             EXPERIMENTS.md §Beyond-paper.",
            best.design.n, best.design.m, best.resources.total.dsps
        );
    }

    println!("\nPareto frontier (performance vs power):");
    for e in pareto(&evals) {
        println!(
            "  (n={}, m={})  {:>6.1} GFlop/s  {:>5.1} W  u={:.3}",
            e.design.n, e.design.m, e.timing.performance_gflops, e.power_w,
            e.timing.utilization
        );
    }

    // the paper's §III observations, checked mechanically:
    let get = |n: u32, m: u32| {
        evals
            .iter()
            .find(|e| e.design.n == n && e.design.m == m)
            .expect("evaluated")
    };
    // 1) x1 designs keep u ~ 1; x2 and x4 are bandwidth-bound
    assert!(get(1, 4).timing.utilization > 0.99);
    assert!(get(2, 1).timing.utilization < 0.6);
    assert!(get(4, 1).timing.utilization < 0.3);
    // 2) cascading keeps the bandwidth requirement of one pipeline
    assert!((get(1, 4).timing.demand_gbps - 7.2).abs() < 0.01);
    // 3) the four-PE cascade consumes ~3.5x the memory of the x4-wide
    //    PE (paper: "3.5 times more on-chip memories")
    let ratio = get(1, 4).resources.core.bram_bits as f64
        / get(4, 1).resources.core.bram_bits as f64;
    println!("\nBRAM ratio (1,4)/(4,1) = {ratio:.2} (paper: 3.48)");
    assert!((ratio - 3.48).abs() < 0.4);
    // 4) nm = 8 designs exceed the device (the paper stopped at nm = 4)
    assert!(evals
        .iter()
        .filter(|e| e.design.n * e.design.m == 8)
        .all(|e| e.infeasible.is_some()));

    println!(
        "\nexplored {} designs ({} feasible) in {:.1}s of job time across {} workers",
        metrics.completed,
        metrics.feasible,
        metrics.total_seconds(),
        coord.workers
    );
    Ok(())
}
