//! Design-space exploration through the `dse` engine (the paper's
//! §III, scaled up): sweep (n, m) up to 8×8 on the 720×300 grid across
//! two devices, with branch-and-bound pruning and a shared evaluation
//! cache — and reproduce the paper's conclusion that the purely
//! temporal (1, 4) design wins performance per watt on the Stratix V.
//!
//! Run: `cargo run --release --example design_space_exploration`

use spdx::dse::{
    BoundedPrune, DesignSpace, EvalCache, Exhaustive, SearchStrategy, Session,
    SweepContext,
};
use spdx::report;
use spdx::resource::{ARRIA_10_GX1150, STRATIX_V_5SGXEA7};

fn main() -> spdx::Result<()> {
    let space = DesignSpace {
        workload: "lbm",
        grids: vec![(720, 300)],
        max_n: 8,
        max_m: 8,
        devices: vec![&STRATIX_V_5SGXEA7, &ARRIA_10_GX1150],
        ddr_variants: vec![Default::default()],
        passes: 2,
        latency: Default::default(),
    };
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let cache = EvalCache::new();
    let ctx = SweepContext::new(&cache, workers);

    println!(
        "space: {} candidates ((n, m) up to {}x{}, {} devices)\n",
        space.len(),
        space.max_n,
        space.max_m,
        space.devices.len()
    );

    // 1. pruned sweep: skips provably-infeasible deep/wide designs
    let pruned = BoundedPrune::default().run(&space, &ctx)?;
    print!("{}", report::sweep_summary(&pruned));

    // 2. exhaustive sweep over the same space, same cache: everything
    //    the pruner evaluated comes back as a cache hit
    let full = Exhaustive.run(&space, &ctx)?;
    println!(
        "exhaustive afterwards: {} evaluated fresh, {} from cache\n",
        full.evaluated, full.cache_hits
    );
    println!("{}", report::dse_table(&full.evals));

    // the paper's conclusions, checked mechanically on the Stratix V
    let stratix: Vec<_> = full
        .evals
        .iter()
        .filter(|e| e.device == "Stratix V 5SGXEA7")
        .collect();
    let get = |n: u32, m: u32| {
        stratix
            .iter()
            .find(|e| e.design.n == n && e.design.m == m)
            .expect("evaluated")
    };
    // 1) within the paper's nm <= 4 sweep, pure temporal (1, 4) wins
    let paper_best = stratix
        .iter()
        .filter(|e| e.infeasible.is_none() && e.design.n * e.design.m <= 4)
        .max_by(|a, b| a.perf_per_watt.total_cmp(&b.perf_per_watt))
        .unwrap();
    assert_eq!((paper_best.design.n, paper_best.design.m), (1, 4));
    println!(
        "paper-space winner : (1, 4) at {:.3} GFlop/sW (paper: 2.416)",
        paper_best.perf_per_watt
    );
    // 2) x1 designs keep u ~ 1; x2 and x4 are bandwidth-bound
    assert!(get(1, 4).timing.utilization > 0.99);
    assert!(get(2, 1).timing.utilization < 0.6);
    assert!(get(4, 1).timing.utilization < 0.3);
    // 3) cascading keeps the bandwidth requirement of one pipeline
    assert!((get(1, 4).timing.demand_gbps - 7.2).abs() < 0.01);
    // 4) the four-PE cascade consumes ~3.5x the memory of the x4-wide
    //    PE (paper: "3.5 times more on-chip memories")
    let ratio = get(1, 4).resources.core.bram_bits as f64
        / get(4, 1).resources.core.bram_bits as f64;
    println!("BRAM ratio (1,4)/(4,1) = {ratio:.2} (paper: 3.48)");
    assert!((ratio - 3.48).abs() < 0.4);
    // 5) nm = 8 designs exceed the Stratix V (the paper stopped at 4) —
    //    which is exactly what the pruner skips without compiling
    assert!(stratix
        .iter()
        .filter(|e| e.design.n * e.design.m == 8)
        .all(|e| e.infeasible.is_some()));

    // the bigger part changes the conclusion: deeper cascades fit
    let arria_best = full
        .evals
        .iter()
        .filter(|e| e.device == "Arria 10 GX1150" && e.infeasible.is_none())
        .max_by(|a, b| a.perf_per_watt.total_cmp(&b.perf_per_watt))
        .unwrap();
    println!(
        "Arria 10 winner    : ({}, {}) at {:.3} GFlop/sW",
        arria_best.design.n, arria_best.design.m, arria_best.perf_per_watt
    );
    assert!(arria_best.design.m > 4, "the bigger part rewards deeper cascades");

    println!("\nPareto frontier (performance vs power, both devices):");
    for e in full.pareto() {
        println!(
            "  ({}, {}) on {:<18} {:>6.1} GFlop/s  {:>5.1} W  u={:.3}",
            e.design.n,
            e.design.m,
            e.device,
            e.timing.performance_gflops,
            e.power_w,
            e.timing.utilization
        );
    }

    // 3. sessions: persist the sweep, reload it, and show that a
    //    resumed sweep recomputes nothing
    let path = std::env::temp_dir()
        .join(format!("spdx_dse_example_session_{}.json", std::process::id()));
    Session::from_sweep(&full, &space).save(&path)?;
    let loaded = Session::load(&path)?;
    let cache2 = EvalCache::new();
    loaded.preload(&cache2);
    let ctx2 = SweepContext::new(&cache2, workers);
    let resumed = Exhaustive.run(&space, &ctx2)?;
    println!(
        "\nsession: {} rows saved to {}; resumed sweep: {} recomputed, {} from session",
        loaded.rows.len(),
        path.display(),
        resumed.evaluated,
        resumed.cache_hits
    );
    assert_eq!(resumed.evaluated, 0);
    std::fs::remove_file(&path).ok();
    Ok(())
}
