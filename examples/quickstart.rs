//! Quickstart: the paper's own Figs. 3–5 example, end to end.
//!
//! Parses the 12-line SPD core of Fig. 4 (Eqs. 5–9), compiles it to a
//! delay-balanced pipeline (Fig. 3b/3c), prints the schedule and DOT
//! graph, streams data through the cycle-accurate engine, and then
//! builds the hierarchical Fig. 5 structure that instantiates the core
//! three times with cross-coupled branch ports.
//!
//! Run: `cargo run --release --example quickstart`

use std::collections::HashMap;

use spdx::dfg;
use spdx::sim::Engine;
use spdx::spd::Registry;

/// Fig. 4, verbatim structure (Eqs. 5–9 of the paper).
const FIG4: &str = r#"
    Name core;                         # name of this core
    Main_In  {main_i::x1,x2,x3,x4};    # main stream in
    Main_Out {main_o::z1,z2};          # main stream out
    Brch_In  {brch_i::bin1};           # branch inputs
    Brch_Out {brch_o::bout1};          # branch outputs

    Param cnst = 123.456;              # define parameter
    EQU Node1, t1 = x1 * x2;           # eq (5) (Node1)
    EQU Node2, t2 = x3 + x4;           # eq (6) (Node2)
    EQU Node3, z1 = t1 - t2 * bin1;    # eq (7) (Node3)
    EQU Node4, z2 = t1 / t2 + cnst;    # eq (8) (Node4)
    DRCT (bout1) = (t2);               # port connection
"#;

fn main() -> spdx::Result<()> {
    // ---- compile the Fig. 4 core -----------------------------------
    let mut registry = Registry::with_library();
    let core = registry.register_source(FIG4)?;
    let compiled = dfg::compile(&core, &registry)?;
    let census = compiled.graph.census();

    println!("== Fig. 4 core ==");
    println!("pipeline depth    : {} stages", compiled.depth());
    println!(
        "FP operators      : {} add/sub, {} mul, {} div (paper DFG: 6 ops)",
        census.add, census.mul, census.div
    );
    println!(
        "balancing stages  : {} (inserted delays, Fig. 3b)",
        compiled.schedule.total_balance_stages
    );

    // ---- stream data through the cycle-accurate pipeline ------------
    let mut engine = Engine::new(&compiled.graph, &compiled.schedule)?;
    let streams: HashMap<String, Vec<f32>> = [
        ("x1", vec![1.0f32, 2.0, 3.0]),
        ("x2", vec![4.0, 5.0, 6.0]),
        ("x3", vec![0.5, 1.5, 2.5]),
        ("x4", vec![0.5, 0.5, 0.5]),
        ("bin1", vec![1.0, 1.0, 2.0]),
    ]
    .into_iter()
    .map(|(k, v)| (k.to_string(), v))
    .collect();
    let out = engine.run_frame(&streams)?;
    println!("z1 stream         : {:?}", out["z1"]);
    println!("z2 stream         : {:?}", out["z2"]);
    // z2 = x1*x2/(x3+x4) + cnst — a pure main-stream path, exact:
    assert!((out["z2"][0] - (4.0 / 1.0 + 123.456)).abs() < 1e-3);
    assert!((out["z2"][2] - (18.0 / 3.0 + 123.456)).abs() < 1e-3);
    // z1 reads bin1 through a *branch* port: branch connections are
    // excluded from delay balancing (their timing is the designer's
    // responsibility — paper Fig. 3d), so within this short frame the
    // branch operand is still the buffer's initial zeros and
    // z1 = t1 - t2*0 = x1*x2:
    assert_eq!(out["z1"], vec![4.0, 10.0, 18.0]);

    // ---- Fig. 5: hierarchical structure with branch coupling --------
    let fig5 = format!(
        "Name Array;
         Main_In {{main_i::i1,i2,i3,i4,i5,i6,i7,i8}};
         Main_Out {{main_o::o1,o2,o3}};
         HDL Node_a, {d}, (t1,t2)(b_a) = core(i1,i2,i3,i4)(b_b);
         HDL Node_b, {d}, (t3,t4)(b_b) = core(i5,i6,i7,i8)(b_a);
         HDL Node_c, {d}, (o1,o2)(b_c) = core(t1,t2,t3,t4)(b_a);
         EQU Node_d, o3 = t2 * t4;",
        d = compiled.depth()
    );
    let array = registry.register_source(&fig5)?;
    let arr = dfg::compile(&array, &registry)?;
    println!("\n== Fig. 5 hierarchical core ==");
    println!("modular depth     : {} stages", arr.depth());
    println!(
        "flat FP operators : {} (3 instances x 6 + 1)",
        arr.graph.census().total()
    );
    assert_eq!(arr.graph.census().total(), 19);

    println!("\nDOT graph of the Fig. 4 DFG (paper Fig. 3a):");
    println!("{}", dfg::to_dot(&compiled.hier_graph, Some(&compiled.hier_schedule)));
    Ok(())
}
